"""Training launcher: ``python -m repro.launch.train --arch qwen3-1.7b
--reduced --steps 50``.

Builds mesh + sharding rules, jits the train step with explicit
in/out_shardings, streams the synthetic token pipeline, checkpoints
periodically. The same ``make_train_step`` is lowered (never executed) by
the multi-pod dry-run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs import get_config
from repro.data.pipeline import ShardedTokenPipeline
from repro.data.synthetic import token_batches
from repro.models import frontends
from repro.models.losses import lm_loss
from repro.models.transformer import TransformerLM
from repro.sharding import use_rules
from repro.sharding.rules import (batch_sharding, default_activation_rules,
                                  param_shardings, replicated)


def make_optimizer(cfg, steps: int = 10_000, peak_lr: float = 3e-4):
    """Adafactor for the >=100B configs (AdamW fp32 moments for 671B exceed
    16 GB/chip x 256 — DESIGN.md §4); AdamW otherwise."""
    sched = optim.linear_warmup_cosine(peak_lr, min(1000, steps // 10 + 1),
                                       steps)
    big = cfg.d_model >= 6144
    return optim.adafactor(sched) if big else optim.adamw(sched)


def make_train_step(cfg, optimizer, remat: bool = True,
                    prefix_embeddings: bool = None, accum_steps: int = 1):
    """``accum_steps > 1``: gradient accumulation over microbatches (the
    batch's leading dim is split), bounding activation memory at
    1/accum_steps of the global batch (§Perf A6)."""
    has_prefix = cfg.n_prefix_tokens > 0

    def grads_of(params, batch, prefix_emb):
        def loss_fn(p):
            return lm_loss(p, cfg, batch,
                           prefix_emb if has_prefix else None,
                           remat=remat)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch, prefix_emb=None):
        if accum_steps == 1:
            (loss, metrics), grads = grads_of(params, batch, prefix_emb)
        else:
            B = batch.shape[0]
            assert B % accum_steps == 0
            mb = batch.reshape(accum_steps, B // accum_steps,
                               *batch.shape[1:])
            pe = (None if prefix_emb is None else
                  prefix_emb.reshape(accum_steps, B // accum_steps,
                                     *prefix_emb.shape[1:]))

            def body(acc, xs):
                (l, m), g = grads_of(params, xs[0],
                                     xs[1] if pe is not None else None)
                g32 = jax.tree.map(lambda a: a.astype(jnp.float32), g)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g32), acc_l + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (mb, pe) if pe is not None else (mb, mb)
            (gsum, lsum), metrics = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), xs)
            grads = jax.tree.map(lambda a: a / accum_steps, gsum)
            loss = lsum / accum_steps
            metrics = jax.tree.map(lambda a: a[-1], metrics)

        grads = optim.zero_frozen(grads)
        grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def shard_jit_train_step(cfg, optimizer, mesh, batch_shape, remat=True,
                         accum_steps=None):
    """jit with explicit shardings, using abstract params (no allocation)."""
    import os as _os
    if accum_steps is None:
        # §Perf A6 default: microbatch the >=100B-class models (4-way) —
        # activation memory scales 1/accum (387->69 GB/dev on jamba train).
        default = "4" if cfg.d_model >= 6144 else "1"
        accum_steps = int(_os.environ.get("REPRO_ACCUM_STEPS", default))
    no_tp = _os.environ.get("REPRO_NO_TP") == "1"
    params_shape = jax.eval_shape(
        lambda: TransformerLM.init(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    p_shard = param_shardings(params_shape, mesh, no_tp=no_tp)
    o_shard = param_shardings(opt_shape, mesh, no_tp=no_tp)
    b_shard = batch_sharding(mesh, no_tp=no_tp)
    step = make_train_step(cfg, optimizer, remat=remat,
                           accum_steps=accum_steps)

    in_sh = (p_shard, o_shard, b_shard)
    args = [params_shape, opt_shape,
            jax.ShapeDtypeStruct(batch_shape, jnp.int32)]
    if cfg.n_prefix_tokens:
        in_sh = in_sh + (b_shard,)
        args.append(frontends.prefix_spec(cfg, batch_shape[0]))
    jitted = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(p_shard, o_shard, replicated(mesh)))
    return jitted, args, (p_shard, o_shard)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    optimizer = make_optimizer(cfg, args.steps, args.lr)

    key = jax.random.PRNGKey(0)
    params = TransformerLM.init(key, cfg)
    opt_state = optimizer.init(params)
    start = 0
    if args.ckpt_dir and (s := latest_step(args.ckpt_dir)) is not None:
        params = restore_pytree(args.ckpt_dir, s)
        start = s
        print(f"restored step {s}")

    step_fn = jax.jit(make_train_step(cfg, optimizer, remat=False))
    pipe = ShardedTokenPipeline(
        token_batches(max(512, args.batch * 8), args.batch, args.seq,
                      cfg.vocab), mesh)
    rules = default_activation_rules(mesh)

    with mesh, use_rules(mesh, rules):
        t0 = time.time()
        for it, batch in zip(range(start, args.steps), pipe):
            pre = (frontends.random_prefix(jax.random.fold_in(key, it), cfg,
                                           args.batch)
                   if cfg.n_prefix_tokens else None)
            if pre is not None:
                params, opt_state, m = step_fn(params, opt_state, batch, pre)
            else:
                params, opt_state, m = step_fn(params, opt_state, batch)
            if (it + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                print(f"step {it+1} loss {float(m['loss']):.4f} "
                      f"xent {float(m['xent']):.4f} {dt*1e3:.0f} ms/step")
                t0 = time.time()
            if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
                save_pytree(params, args.ckpt_dir, it + 1)
    print("done")


if __name__ == "__main__":
    main()
