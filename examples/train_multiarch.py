"""End-to-end training driver across the assigned architecture zoo.

Runs a short training job (reduced config) for any/all of the 10 assigned
architectures through the real launcher path (optimizer, grad clip, forecast
heads where configured, checkpointing).

    PYTHONPATH=src python examples/train_multiarch.py --arch rwkv6-7b
    PYTHONPATH=src python examples/train_multiarch.py --all --steps 20
"""
import argparse

from repro.configs import ARCHS
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    targets = list(ARCHS) if args.all else [args.arch]
    for arch in targets:
        print(f"=== {arch} (reduced) ===")
        train_main(["--arch", arch, "--reduced", "--steps", str(args.steps),
                    "--batch", "4", "--seq", "64", "--log-every", "10"])


if __name__ == "__main__":
    main()
