"""End-to-end serving driver: batched requests through the predictive-
sampling engine with continuous batching (deliverable b, serving flavour).

Trains a reduced qwen3-family LM on repetitive token streams, then serves a
queue of ragged requests, reporting verify rounds vs the 1-call-per-token
ancestral baseline. Any of the 10 assigned architectures can be substituted
via --arch.

    PYTHONPATH=src python examples/serve_llm.py --arch qwen3-1.7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.data.synthetic import repetitive_tokens
from repro.engine import PredictiveSampler, Request
from repro.models.losses import lm_loss
from repro.models.transformer import TransformerLM
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--train-steps", type=int, default=250)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--rounds-per-sync", type=int, default=8,
                    help="max verify rounds per device dispatch")
    ap.add_argument("--staging-slots", type=int, default=4,
                    help="pre-staged requests per shard for in-loop slot "
                         "adoption (DESIGN.md §15); 0 disables staging and "
                         "restores host-only admission")
    ap.add_argument("--adaptive-rounds", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="retune rounds_per_sync from observed idle "
                         "row-rounds (default: on exactly when staging is "
                         "on; requires --staging-slots > 0)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"training reduced {cfg.name} on repetitive streams ...")
    data = repetitive_tokens(256, 64, cfg.vocab, seed=0)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(2e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (l, _), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
        g = optim.zero_frozen(g)
        u, state = opt.update(g, state, params)
        return optim.apply_updates(params, u), state, l

    rng = np.random.default_rng(0)
    for it in range(args.train_steps):
        params, state, l = step(
            params, state, jnp.asarray(data[rng.integers(0, 256, 16)]))
    print(f"  final loss {float(l):.3f}")

    batcher = ServingEngine(
        cfg, params, batch=2, window_max=args.window, max_len=128,
        eps_key=jax.random.PRNGKey(1), adaptive=False,
        rounds_per_sync=args.rounds_per_sync,
        staging_slots=args.staging_slots,
        adaptive_rounds=args.adaptive_rounds)
    for i in range(args.requests):
        prompt = repetitive_tokens(1, int(rng.integers(4, 10)), cfg.vocab,
                                   seed=100 + i)[0]
        batcher.submit(Request(uid=i, prompt=prompt,
                               new_tokens=int(rng.integers(16, 40))))

    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    rounds = int(np.asarray(batcher.state.rounds))
    total = sum(r.new_tokens for r in done)
    print(f"\nserved {len(done)} requests / {total} new tokens")
    print(f"verify rounds: {rounds} -> {100.0*rounds/total:.1f}% of the "
          f"ancestral baseline ({dt:.1f}s on CPU)")
    for r in done:
        print(f"  req {r.uid}: +{r.new_tokens} tok, "
              f"{r.calls_used} calls, tail={r.result[-8:]}")
    m = batcher.export_metrics()
    print(f"telemetry: p50={m['latency_p50_s']:.2f}s "
          f"p95={m['latency_p95_s']:.2f}s "
          f"occupancy={m['mean_batch_occupancy']:.2f} "
          f"blocks_in_use={m['blocks_in_use']}")
    print(f"residency: syncs/token={m['syncs_per_token']:.3f} "
          f"rounds/sync={m['rounds_per_sync']:.2f} "
          f"in-loop adoptions={m['in_loop_adoptions']} "
          f"(staged {m['staged_sequences']}, "
          f"k_final={m['rounds_per_sync_final']})")


if __name__ == "__main__":
    main()
