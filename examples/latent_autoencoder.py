"""Paper §4.2 end-to-end: discrete autoencoder + latent ARM + predictive
sampling, two-phase training exactly as the paper prescribes.

  phase 1: train the ST-argmax autoencoder (MSE);
  phase 2: freeze it, train a PixelCNN on encoder latents (+ forecasting
           module, joint, loss weight 0.01);
  sample:  FPI in latent space -> decode to images; verify exactness.

    PYTHONPATH=src python examples/latent_autoencoder.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.table2_latent import train_autoencoder
from benchmarks.common import train_pixelcnn
from repro.configs.paper import AE_REDUCED, LATENT_ARM_REDUCED, forecast_cfg
from repro.core import forecasting as fc
from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.data.synthetic import quantized_textures
from repro.models.autoencoder import DiscreteAutoencoder as AE
from repro.models.pixelcnn import PixelCNN


def main():
    ae_cfg, arm_cfg = AE_REDUCED, LATENT_ARM_REDUCED
    data = quantized_textures(512, ae_cfg.height, ae_cfg.width, 3, 256,
                              seed=0)
    print("phase 1: training the discrete autoencoder ...")
    ae_params, mse = train_autoencoder(ae_cfg, data, steps=250)
    print(f"  MSE {mse:.4f} (paper: 0.0065 CIFAR10 at full scale)")

    print("phase 2: frozen encoder -> latents -> PixelCNN prior ...")
    x = jnp.asarray(data, jnp.float32) / 127.5 - 1.0
    z, _ = AE.quantize(AE.encode_logits(ae_params, x, ae_cfg))
    fcfg = forecast_cfg(arm_cfg, horizon=1)
    arm_params, fparams = train_pixelcnn(arm_cfg, np.asarray(z), steps=250,
                                         forecast_cfg=fcfg)

    print("sampling latents with fixed-point iteration ...")
    arm_fn = PixelCNN.make_arm_fn(arm_params, arm_cfg)
    eps = reparam.gumbel(jax.random.PRNGKey(3),
                         (4, arm_cfg.d, arm_cfg.categories))
    z_ref, st_ref = ps.ancestral_sample(arm_fn, eps)
    z_fpi, st_fpi = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    exact = bool((np.asarray(z_ref) == np.asarray(z_fpi)).all())
    print(f"  ancestral {int(st_ref.arm_calls)} calls vs "
          f"FPI {int(st_fpi.arm_calls)} calls; exact: {exact}")

    z_img = z_fpi.reshape(4, *ae_cfg.latent_hw, ae_cfg.latent_channels)
    xhat = AE.decode(ae_params,
                     jax.nn.one_hot(z_img, ae_cfg.latent_categories),
                     ae_cfg)
    print(f"  decoded images: {xhat.shape}, "
          f"finite: {bool(jnp.all(jnp.isfinite(xhat)))}")


if __name__ == "__main__":
    main()
