"""Quickstart: predictive sampling of a PixelCNN in ~2 minutes on CPU.

Trains a tiny PixelCNN on procedural binary stroke images, then samples with
(a) naive ancestral sampling, (b) ARM fixed-point iteration (paper Alg. 2),
and shows the samples are bit-identical while FPI uses a fraction of the
ARM calls.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro import optim
from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.data.synthetic import binary_strokes
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig


def main():
    cfg = PixelCNNConfig(height=12, width=12, channels=1, categories=2,
                         filters=24, n_res=2, first_kernel=5)
    print(f"training a {cfg.filters}-filter PixelCNN on "
          f"{cfg.height}x{cfg.width} binary strokes ...")
    data = jax.numpy.asarray(binary_strokes(256, 12, 12, seed=0))
    params = PixelCNN.init(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(2e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        l, g = jax.value_and_grad(
            lambda p: PixelCNN.bpd(p, batch, cfg))(params)
        g = optim.zero_frozen(g)
        u, state = opt.update(g, state, params)
        return optim.apply_updates(params, u), state, l

    rng = np.random.default_rng(0)
    for it in range(200):
        params, state, l = step(params, state,
                                data[rng.integers(0, 256, size=32)])
        if (it + 1) % 50 == 0:
            print(f"  step {it+1}: {float(l):.3f} bits/dim")

    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    eps = reparam.gumbel(jax.random.PRNGKey(7), (4, cfg.d, cfg.categories))

    t0 = time.time()
    x_naive, st_naive = jax.jit(
        lambda e: ps.ancestral_sample(arm_fn, e))(eps)
    jax.block_until_ready(x_naive)
    t_naive = time.time() - t0

    t0 = time.time()
    x_fpi, st_fpi = jax.jit(
        lambda e: ps.predictive_sample(arm_fn, ps.fpi_forecast, e))(eps)
    jax.block_until_ready(x_fpi)
    t_fpi = time.time() - t0

    exact = bool((np.asarray(x_naive) == np.asarray(x_fpi)).all())
    print(f"\nancestral: {int(st_naive.arm_calls)} ARM calls "
          f"({t_naive:.2f}s incl. compile)")
    print(f"FPI:       {int(st_fpi.arm_calls)} ARM calls "
          f"({t_fpi:.2f}s incl. compile)")
    print(f"samples bit-identical: {exact}   "
          f"(paper claim 3: exact samples from the true model)")

    img = np.asarray(x_fpi)[0].reshape(12, 12)
    print("\na sample:")
    for row in img:
        print("  " + "".join("#" if v else "." for v in row))


if __name__ == "__main__":
    main()
