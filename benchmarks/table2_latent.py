"""Paper Table 2 analogue: predictive sampling of the latent-space ARM.

Two-phase training (paper §4.2): discrete autoencoder on textures, freeze,
then PixelCNN on encoder latents. Measures ARM-call % in latent space."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (check_exactness, sampling_run, train_pixelcnn)
from repro import optim
from repro.configs.paper import AE_REDUCED, LATENT_ARM_REDUCED, forecast_cfg
from repro.core import forecasting as fc
from repro.core import predictive_sampling as ps
from repro.data.synthetic import quantized_textures
from repro.models.autoencoder import DiscreteAutoencoder as AE
from repro.models.pixelcnn import PixelCNN


def train_autoencoder(cfg, data, steps=300, lr=2e-3, seed=0):
    params = AE.init(jax.random.PRNGKey(seed), cfg)
    opt = optim.adamw(lr)
    state = opt.init(params)
    x = jnp.asarray(data, jnp.float32) / (255.0 / 2) - 1.0

    @jax.jit
    def step(params, state, batch):
        l, g = jax.value_and_grad(
            lambda p: AE.mse_loss(p, batch, cfg))(params)
        u, state = opt.update(g, state, params)
        return optim.apply_updates(params, u), state, l

    rng = np.random.default_rng(seed)
    for it in range(steps):
        idx = rng.integers(0, x.shape[0], size=16)
        params, state, l = step(params, state, x[idx])
    return params, float(l)


def run(fast: bool = True):
    steps = 250 if fast else 1500
    ae_cfg, arm_cfg = AE_REDUCED, LATENT_ARM_REDUCED
    data = quantized_textures(512, ae_cfg.height, ae_cfg.width, 3, 256,
                              seed=3)
    ae_params, mse = train_autoencoder(ae_cfg, data, steps=steps)

    # frozen encoder -> latent dataset
    x = jnp.asarray(data, jnp.float32) / (255.0 / 2) - 1.0
    logits = AE.encode_logits(ae_params, x, ae_cfg)
    z, _ = AE.quantize(logits)                       # (N, h, w, CL)
    z = np.asarray(z)

    fcfg = forecast_cfg(arm_cfg, horizon=1)
    params, fparams = train_pixelcnn(arm_cfg, z, steps=steps,
                                     forecast_cfg=fcfg)
    arm_fn = PixelCNN.make_arm_fn(params, arm_cfg)
    module = fc.PixelForecast.module_fn(fparams, fcfg)
    forecast = ps.make_learned_forecast(module, window=arm_cfg.channels,
                                        group=arm_cfg.channels)
    check_exactness(arm_fn, arm_cfg, forecast=forecast)

    rows = []
    for batch in (1, 16):
        for m in ("baseline", "fpi", "forecast"):
            c, cs, t, ts = sampling_run(arm_fn, m, arm_cfg, batch,
                                        list(range(5)), forecast=forecast)
            rows.append({
                "table": "table2", "dataset": "latent-AE(textures)",
                "batch": batch, "method": m, "calls_pct": round(c, 1),
                "calls_std": round(cs, 2), "time_s": round(t, 4),
                "time_std": round(ts, 4), "ae_mse": round(mse, 5),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
