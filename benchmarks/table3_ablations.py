"""Paper Table 3 analogue: ablations.

1. Reparametrization: forecast WITHOUT the shared Gumbel noise (most-likely
   value instead of reparametrized sample) — paper: 25.9% -> 97.2% calls.
2. Representation sharing: forecasting module trained on raw one-hot x
   instead of the shared ARM representation h — paper: 50.9% -> 67.1%.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import sampling_run, train_pixelcnn
from repro.configs.paper import forecast_cfg
from repro.core import forecasting as fc
from repro.core import predictive_sampling as ps
from repro.data.synthetic import quantized_textures
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig


def run(fast: bool = True):
    steps = 250 if fast else 1500
    cfg = PixelCNNConfig(height=8, width=8, channels=3, categories=16,
                         filters=24, n_res=2, first_kernel=5)
    data = quantized_textures(512, 8, 8, 3, 16, seed=4)
    fcfg = forecast_cfg(cfg, horizon=2)
    params, fparams = train_pixelcnn(cfg, data, steps=steps,
                                     forecast_cfg=fcfg)
    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    module = fc.PixelForecast.module_fn(fparams, fcfg)
    window = fcfg.horizon * cfg.channels

    rows = []
    batch = 16

    # --- reparametrization ablation -------------------------------------
    for name, use_noise in (("fpi+reparam", True),):
        c, cs, t, ts = sampling_run(arm_fn, "fpi", cfg, batch, range(5))
        rows.append({"table": "table3", "ablation": "with reparam (FPI)",
                     "batch": batch, "calls_pct": round(c, 1),
                     "time_s": round(t, 4)})
    # without reparametrization: the "forecast" is the mode of P_F, verified
    # against a *sampled* output — emulated by forecasting with zero noise.
    no_reparam = ps.make_learned_forecast(module, window=window,
                                          group=cfg.channels,
                                          use_reparam_noise=False)
    fn = jax.jit(lambda eps: ps.predictive_sample(arm_fn, no_reparam, eps))
    from repro.core import reparam as rp
    calls = []
    for seed in range(5):
        eps = rp.gumbel(jax.random.PRNGKey(seed),
                        (batch, cfg.d, cfg.categories))
        _, stats = fn(eps)
        calls.append(100.0 * int(stats.arm_calls) / cfg.d)
    rows.append({"table": "table3", "ablation": "without reparametrization",
                 "batch": batch, "calls_pct": round(float(np.mean(calls)), 1),
                 "time_s": None})

    # --- representation sharing ablation ---------------------------------
    c, cs, t, ts = sampling_run(
        arm_fn, "forecast", cfg, batch, range(5),
        forecast=ps.make_learned_forecast(module, window=window,
                                          group=cfg.channels))
    rows.append({"table": "table3", "ablation": "forecast w/ shared h",
                 "batch": batch, "calls_pct": round(c, 1),
                 "time_s": round(t, 4)})

    # module trained WITHOUT h: triangular conv applied to one-hot x instead
    cfg_nox = fc.PixelForecastConfig(channels=cfg.channels,
                                     categories=cfg.categories,
                                     horizon=fcfg.horizon,
                                     filters=fcfg.filters,
                                     in_filters=cfg.channels * cfg.categories)
    fparams_nox = _train_forecast_on_x(cfg, cfg_nox, params, data,
                                       steps=steps)
    module_nox = _module_on_x(fparams_nox, cfg, cfg_nox)
    c, cs, t, ts = sampling_run(
        arm_fn, "forecast", cfg, batch, range(5),
        forecast=ps.make_learned_forecast(module_nox, window=window,
                                          group=cfg.channels, takes_x=True))
    rows.append({"table": "table3", "ablation": "forecast w/o shared h",
                 "batch": batch, "calls_pct": round(c, 1),
                 "time_s": round(t, 4)})
    return rows


def _module_on_x(fparams, pix_cfg, fcfg):
    """Per-sample forecasting module over one-hot x (no shared h)."""
    import jax.numpy as jnp

    def fn(x_flat):
        img = x_flat.reshape(pix_cfg.height, pix_cfg.width,
                             pix_cfg.channels)
        oh = PixelCNN.onehot(img[None], pix_cfg)
        return fc.PixelForecast.apply(fparams, oh, fcfg)[0]
    return fn


def _train_forecast_on_x(pix_cfg, fcfg, arm_params, data, steps, seed=7):
    """Train the x-only module against the frozen ARM's logits (Eq. 9)."""
    import jax.numpy as jnp
    from repro import optim

    fparams = fc.PixelForecast.init(jax.random.PRNGKey(seed), fcfg)
    opt = optim.adamw(2e-3)
    state = opt.init(fparams)
    data = jnp.asarray(data)

    @jax.jit
    def step(fp, state, batch):
        logits, _ = PixelCNN.forward_int(arm_params, batch, pix_cfg)
        B = batch.shape[0]
        arm_logits = logits.reshape(B, pix_cfg.height * pix_cfg.width,
                                    pix_cfg.channels, pix_cfg.categories)
        oh = PixelCNN.onehot(batch, pix_cfg)

        def loss(fp):
            out = fc.PixelForecast.apply(fp, oh, fcfg)
            return fc.PixelForecast.kl_loss(out, arm_logits, fcfg)

        l, g = jax.value_and_grad(loss)(fp)
        g = optim.zero_frozen(g)
        u, state2 = opt.update(g, state, fp)
        return optim.apply_updates(fp, u), state2, l

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, data.shape[0], size=32)
        fparams, state, _ = step(fparams, state, data[idx])
    return fparams


if __name__ == "__main__":
    for r in run():
        print(r)
