"""Roofline analysis (deliverable g) from the dry-run artifacts.

Terms per (arch x shape), single-pod mesh (16x16 = 256 chips):

  compute    = HLO_FLOPs / (chips x 197e12)
  memory     = HLO_bytes / (chips x 819e9)
  collective = collective_bytes / (chips x 50e9)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed) and the
post-SPMD HLO text (collective operand bytes; launch/dryrun.py parser).

SCAN CORRECTION: XLA's cost analysis counts a ``while``-loop body ONCE, but
our layer stacks run the scanned block ``n_blocks`` times. We correct by
lowering two reduced-depth variants of each config (k=0 and k=1 scanned
blocks, same prefix/suffix) on the same mesh:

  per_block  = cost(k=1) - cost(k=0)
  corrected  = cost(k=0)_fullshape + n_blocks * per_block

The same correction applies to collective bytes (collectives inside the
scan body also appear once in the HLO). Artifacts for the variants are
produced on demand and cached to benchmarks/artifacts/roofline_probe/.

MODEL_FLOPS = 6 * N_active * D_tokens (train: x3 for fwd+bwd... standard
6ND already includes backward; prefill/decode use 2 * N_active * D).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
DRY = os.path.join(ART, "dryrun")
PROBE = os.path.join(ART, "roofline_probe")

CHIPS = 256
PEAK = 197e12
HBM = 819e9
ICI = 50e9

DECODE_WINDOW = 8


def _param_counts(cfg):
    """(total_params, active_params) excluding embeddings (standard 6ND)."""
    D = cfg.d_model
    per_layer_tot, per_layer_act = [], []
    for (mixer, ffn) in cfg.layer_specs():
        if mixer in ("attn", "local"):
            a = D * cfg.n_heads * cfg.head_dim * 2 \
                + D * cfg.n_kv_heads * cfg.head_dim * 2
        elif mixer == "mla":
            a = (D * cfg.q_lora_rank
                 + cfg.q_lora_rank * cfg.n_heads
                 * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                 + D * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                 + cfg.kv_lora_rank * cfg.n_heads
                 * (cfg.qk_nope_dim + cfg.v_head_dim)
                 + cfg.n_heads * cfg.v_head_dim * D)
        elif mixer == "rwkv":
            a = 5 * D * D
        elif mixer == "mamba":
            DI = 2 * D
            a = D * 2 * DI + DI * D + DI * (D // 16 + 2 * cfg.ssm_state)
        glu = cfg.mlp_kind in ("swiglu", "geglu")
        if ffn == "dense":
            f = D * cfg.d_ff * (3 if glu else 2)
        elif ffn == "moe":
            fe = D * (cfg.moe_d_ff or cfg.d_ff) * (3 if glu else 2)
            f = cfg.n_experts * fe + cfg.n_shared_experts * fe
            f_act = cfg.top_k * fe + cfg.n_shared_experts * fe
        elif ffn == "rwkv_cmix":
            f = D * cfg.d_ff * 2 + D * D
        per_layer_tot.append(a + f)
        per_layer_act.append(a + (f_act if ffn == "moe" else f))
    return float(np.sum(per_layer_tot)), float(np.sum(per_layer_act))


def model_flops(cfg, shape):
    """Analytic 'useful' FLOPs for the step (excl. attention quadratic)."""
    _, active = _param_counts(cfg)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * active * toks          # fwd+bwd
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * active * toks
    toks = shape.global_batch * DECODE_WINDOW
    return 2.0 * active * toks


def probe_cost(arch, shape_name, k_blocks: int):
    """Lower the (arch, shape) step with k scanned blocks; cache results."""
    os.makedirs(PROBE, exist_ok=True)
    tag = f"{arch}__{shape_name}__k{k_blocks}"
    path = os.path.join(PROBE, tag + ".json")
    if os.path.exists(path):
        rec = json.load(open(path))
        if rec.get("status") == "ok":
            return rec
    env = dict(os.environ,
               REPRO_OVERRIDE_BLOCKS=str(k_blocks),
               PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape_name, "--out", PROBE],
        env=env, capture_output=True, text=True, cwd=_repo_root())
    src = os.path.join(PROBE, f"{arch}__{shape_name}__pod16x16.json")
    if not os.path.exists(src):
        raise RuntimeError(f"probe failed: {out.stderr[-500:]}")
    rec = json.load(open(src))
    os.rename(src, path)
    return rec


def _repo_root():
    return os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def corrected_costs(arch, shape_name, full_rec, cfg):
    """Apply the scan correction using k=0/k=1 probes."""
    n_blocks = cfg.n_blocks
    if n_blocks <= 1:
        coll = sum(c["bytes"] for c in full_rec["collectives"].values())
        return full_rec["flops"], full_rec["bytes_accessed"], coll, 1.0
    k0 = probe_cost(arch, shape_name, 0)
    k1 = probe_cost(arch, shape_name, 1)

    def coll_bytes(r):
        return sum(c["bytes"] for c in r["collectives"].values())

    def corr(fn):
        per_block = max(0.0, fn(k1) - fn(k0))
        return fn(k0) + n_blocks * per_block

    flops = corr(lambda r: r["flops"])
    bytes_ = corr(lambda r: r["bytes_accessed"])
    coll = corr(coll_bytes)
    return flops, bytes_, coll, None


def analyze(correct_scan: bool = True):
    from repro.configs import ARCHS, SHAPES, get_config
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            path = os.path.join(DRY, f"{arch}__{shape_name}__pod16x16.json")
            if not os.path.exists(path):
                continue
            rec = json.load(open(path))
            if rec["status"] == "skipped":
                rows.append({"arch": arch, "shape": shape_name,
                             "status": "skipped",
                             "reason": rec["reason"][:60]})
                continue
            if rec["status"] != "ok":
                rows.append({"arch": arch, "shape": shape_name,
                             "status": "error"})
                continue
            if correct_scan:
                try:
                    flops, bytes_, coll, _ = corrected_costs(
                        arch, shape_name, rec, cfg)
                except Exception as e:  # noqa: BLE001
                    print(f"probe failed for {arch}/{shape_name}: {e}",
                          file=sys.stderr)
                    flops, bytes_ = rec["flops"], rec["bytes_accessed"]
                    coll = sum(c["bytes"]
                               for c in rec["collectives"].values())
            else:
                flops, bytes_ = rec["flops"], rec["bytes_accessed"]
                coll = sum(c["bytes"] for c in rec["collectives"].values())

            # cost_analysis is per-partition (per-device) on SPMD modules:
            # flops/bytes already divided by the mesh; collective bytes are
            # parsed from the per-device program too.
            t_comp = flops / PEAK
            t_mem = bytes_ / HBM
            t_coll = coll / ICI
            dom = max((t_comp, "compute"), (t_mem, "memory"),
                      (t_coll, "collective"))[1]
            mf = model_flops(cfg, shape)
            ratio = mf / (flops * CHIPS) if flops > 0 else float("nan")
            rows.append({
                "arch": arch, "shape": shape_name, "status": "ok",
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "bottleneck": dom,
                "model_flops": mf,
                "useful_ratio": ratio,
                "mem_per_dev_gb": (rec["memory"].get("temp_size") or 0)
                / 1e9,
            })
    return rows


def to_markdown(rows):
    lines = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
             "bottleneck | MODEL/HLO | temp GB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']}: {r.get('reason','')} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.2f} | "
            f"{r['mem_per_dev_gb']:.2f} |")
    return "\n".join(lines)


def paged_decode_rows(capacities=(4096, 32768, 262144), batch: int = 8,
                      used: int = 2048, window: int = DECODE_WINDOW):
    """Paged-vs-dense-gather serving round, analytic HBM traffic per arch.

    The dense round-trip (gather the full-capacity K/V view, decode,
    scatter the window back) moves ~3x the *capacity* every round; the
    paged kernel streams only each sequence's *used* blocks through its
    block table — per-round traffic independent of how large the pool /
    per-sequence capacity is. Pure shape arithmetic (same spirit as the
    roofline terms), so it covers the full-scale configs, not the reduced
    CPU variants."""
    from benchmarks.serving_bench import round_bytes_model
    from repro.configs import ARCHS, get_config

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        if not any(m in ("attn", "local", "mla")
                   for m, _ in cfg.layer_specs()):
            continue                    # pure-recurrent stacks aren't paged
        for cap in capacities:
            bm = round_bytes_model(cfg, batch, cap, used=used, window=window)
            rows.append({
                "table": "roofline_paged", "arch": arch, "capacity": cap,
                "dense_bytes": bm["dense_bytes"],
                "paged_bytes": bm["paged_bytes"],
                "dense_s": bm["dense_bytes"] / HBM,
                "paged_s": bm["paged_bytes"] / HBM,
                "traffic_ratio": round(bm["dense_bytes"]
                                       / max(1, bm["paged_bytes"]), 1),
            })
    return rows


def paged_to_markdown(rows):
    lines = ["| arch | capacity | dense GB/round | paged GB/round | "
             "dense(s) | paged(s) | ratio |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['capacity']} | "
            f"{r['dense_bytes']/1e9:.3f} | {r['paged_bytes']/1e9:.3f} | "
            f"{r['dense_s']:.2e} | {r['paged_s']:.2e} | "
            f"{r['traffic_ratio']} |")
    return "\n".join(lines)


def run(fast: bool = True):
    rows = analyze(correct_scan=not fast)
    ok = [r for r in rows if r["status"] == "ok"]
    out = [{"table": "roofline", "pairs_ok": len(ok),
            "pairs_total": len(rows),
            "bottlenecks": {b: sum(r["bottleneck"] == b for r in ok)
                            for b in ("compute", "memory", "collective")}}]
    md = to_markdown(rows)
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "roofline.md"), "w") as f:
        f.write(md + "\n")
    paged = paged_decode_rows()
    with open(os.path.join(ART, "roofline_paged.md"), "w") as f:
        f.write(paged_to_markdown(paged) + "\n")
    out.extend(paged)
    return out


if __name__ == "__main__":
    rows = analyze(correct_scan="--fast" not in sys.argv)
    print(to_markdown(rows))
    print()
    print(paged_to_markdown(paged_decode_rows()))
