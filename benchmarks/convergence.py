"""Paper Figure 6 analogue: per-position convergence iteration of FPI.

Prints an ASCII heat map of the iteration at which each pixel converged,
averaged over channels and a batch — the paper's left-column-converges-first
structure is visible in text."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import train_pixelcnn
from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.data.synthetic import quantized_textures
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig

GLYPHS = " .:-=+*#%@"


def run(fast: bool = True):
    steps = 250 if fast else 1000
    cfg = PixelCNNConfig(height=8, width=8, channels=3, categories=16,
                         filters=24, n_res=2, first_kernel=5)
    data = quantized_textures(512, 8, 8, 3, 16, seed=5)
    params, _ = train_pixelcnn(cfg, data, steps=steps)
    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    eps = reparam.gumbel(jax.random.PRNGKey(0), (16, cfg.d, cfg.categories))
    _, stats = jax.jit(lambda e: ps.predictive_sample(
        arm_fn, ps.fpi_forecast, e))(eps)
    conv = np.asarray(stats.converge_iter, np.float64)          # (B, d)
    conv = conv.reshape(16, cfg.height, cfg.width, cfg.channels)
    m = conv.mean(axis=(0, 3))                                   # (H, W)
    lo, hi = m.min(), m.max()
    lines = ["FPI convergence iteration map (baseline would be uniform "
             f"raster 1..{cfg.d}); mean calls: "
             f"{int(np.asarray(stats.arm_calls))}/{cfg.d}"]
    for r in range(cfg.height):
        row = "".join(GLYPHS[int((m[r, c] - lo) / (hi - lo + 1e-9)
                                 * (len(GLYPHS) - 1))]
                      for c in range(cfg.width))
        lines.append(row)
    # structural check: left column converges no later than right column
    left, right = m[:, 0].mean(), m[:, -1].mean()
    lines.append(f"left-col mean iter {left:.1f} <= right-col {right:.1f}: "
                 f"{bool(left <= right)}")
    return [{"table": "convergence", "report": "\n".join(lines),
             "arm_calls": int(np.asarray(stats.arm_calls)), "d": cfg.d,
             "left_mean": round(float(left), 2),
             "right_mean": round(float(right), 2)}]


if __name__ == "__main__":
    print(run()[0]["report"])
