"""Shared benchmark utilities: tiny-scale training loops + sampling timers.

Benchmarks run on the CPU container at reduced scale (DESIGN.md §7): the
*measured quantities* mirror the paper's tables — % of ARM calls vs the
ancestral baseline, wall time per sampled batch — on procedurally generated
stand-in data.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import forecasting as fc
from repro.core import predictive_sampling as ps
from repro.core import reparam
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig


def train_pixelcnn(cfg: PixelCNNConfig, data, steps=300, lr=2e-3, seed=0,
                   forecast_cfg=None, forecast_weight=0.01):
    """Returns (params, fparams|None). Joint ARM + forecasting training
    (paper: shared h, forecasting loss down-weighted 0.01)."""
    key = jax.random.PRNGKey(seed)
    params = PixelCNN.init(key, cfg)
    fparams = (fc.PixelForecast.init(jax.random.fold_in(key, 1), forecast_cfg)
               if forecast_cfg else None)
    opt = optim.adamw(lr)
    state = opt.init((params, fparams) if fparams is not None else params)
    data = jnp.asarray(data)
    n = data.shape[0]

    @jax.jit
    def step(p_all, state, batch):
        def loss(p_all):
            if forecast_cfg is not None:
                p, fp = p_all
            else:
                p, fp = p_all, None
            logits, h = PixelCNN.forward_int(p, batch, cfg)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, batch[..., None], axis=-1)
            nll = -jnp.mean(jnp.sum(ll, axis=(1, 2, 3)))
            nll_bpd = nll / (cfg.d * np.log(2.0))
            if fp is not None:
                B = batch.shape[0]
                arm_logits = logits.reshape(
                    B, cfg.height * cfg.width, cfg.channels, cfg.categories)
                out = fc.PixelForecast.apply(fp, h, forecast_cfg)
                kl = fc.PixelForecast.kl_loss(out, arm_logits, forecast_cfg)
                return nll_bpd + forecast_weight * kl
            return nll_bpd

        l, g = jax.value_and_grad(loss)(p_all)
        g = optim.zero_frozen(g)
        u, state = opt.update(g, state, p_all)
        return optim.apply_updates(p_all, u), state, l

    p_all = (params, fparams) if fparams is not None else params
    rng = np.random.default_rng(seed)
    for it in range(steps):
        idx = rng.integers(0, n, size=min(32, n))
        p_all, state, l = step(p_all, state, data[idx])
    if forecast_cfg is not None:
        return p_all
    return p_all, None


def sampling_run(arm_fn, method, cfg, batch, seeds, forecast=None):
    """Returns (mean_calls_pct, std, mean_time_s, std) over seeds."""
    d, K = cfg.d, cfg.categories
    if method == "baseline":
        fn = jax.jit(lambda eps: ps.ancestral_sample(arm_fn, eps))
    elif method == "fpi":
        fn = jax.jit(lambda eps: ps.predictive_sample(arm_fn,
                                                      ps.fpi_forecast, eps))
    elif method == "zeros":
        fn = jax.jit(lambda eps: ps.predictive_sample(arm_fn,
                                                      ps.zeros_forecast, eps))
    elif method == "last":
        fn = jax.jit(lambda eps: ps.predictive_sample(
            arm_fn, ps.predict_last_forecast, eps))
    elif method == "forecast":
        fn = jax.jit(lambda eps: ps.predictive_sample(arm_fn, forecast, eps))
    else:
        raise ValueError(method)

    calls, times = [], []
    for seed in seeds:
        eps = reparam.gumbel(jax.random.PRNGKey(seed), (batch, d, K))
        x, stats = fn(eps)   # warm-up/compile on first seed
        jax.block_until_ready(x)
        t0 = time.time()
        x, stats = fn(eps)
        jax.block_until_ready(x)
        times.append(time.time() - t0)
        calls.append(100.0 * int(stats.arm_calls) / d)
    return (float(np.mean(calls)), float(np.std(calls, ddof=1)),
            float(np.mean(times)), float(np.std(times, ddof=1)))


def check_exactness(arm_fn, cfg, batch=2, seed=123, forecast=None):
    """Spot-verify the exactness guarantee for a trained model."""
    eps = reparam.gumbel(jax.random.PRNGKey(seed),
                         (batch, cfg.d, cfg.categories))
    x_ref, _ = ps.ancestral_sample(arm_fn, eps)
    x_fpi, _ = ps.predictive_sample(arm_fn, ps.fpi_forecast, eps)
    assert (np.asarray(x_ref) == np.asarray(x_fpi)).all(), "exactness violated!"
    if forecast is not None:
        x_fc, _ = ps.predictive_sample(arm_fn, forecast, eps)
        assert (np.asarray(x_ref) == np.asarray(x_fc)).all()
    return True
