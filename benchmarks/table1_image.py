"""Paper Table 1 analogue: predictive sampling of image ARMs.

Reduced-scale PixelCNNs on procedural stand-ins (binary strokes ~ binary
MNIST; 4-bit / 8-bit textures ~ CIFAR/SVHN). Reports % ARM calls + wall time
for: baseline ancestral / forecast-zeros / predict-last / fixed-point
iteration / + learned forecasting, at batch sizes 1 and 16.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (check_exactness, sampling_run, train_pixelcnn)
from repro.configs.paper import forecast_cfg
from repro.core import forecasting as fc
from repro.core import predictive_sampling as ps
from repro.data.synthetic import binary_strokes, quantized_textures
from repro.models.pixelcnn import PixelCNN, PixelCNNConfig

SEEDS = list(range(5))


def _rows_for(name, cfg, data, horizon, methods, steps, seeds=SEEDS):
    fcfg = forecast_cfg(cfg, horizon)
    (params, fparams) = train_pixelcnn(cfg, data, steps=steps,
                                       forecast_cfg=fcfg)
    arm_fn = PixelCNN.make_arm_fn(params, cfg)
    module = fc.PixelForecast.module_fn(fparams, fcfg)
    forecast = ps.make_learned_forecast(
        module, window=horizon * cfg.channels, group=cfg.channels)
    check_exactness(arm_fn, cfg, forecast=forecast)

    rows = []
    for batch in (1, 16):
        for m in methods:
            c, cs, t, ts = sampling_run(arm_fn, m, cfg, batch, seeds,
                                        forecast=forecast)
            rows.append({
                "table": "table1", "dataset": name, "batch": batch,
                "method": m, "calls_pct": round(c, 1),
                "calls_std": round(cs, 2), "time_s": round(t, 4),
                "time_std": round(ts, 4),
            })
    return rows


def run(fast: bool = True):
    steps = 250 if fast else 1500
    rows = []
    bin_cfg = PixelCNNConfig(height=12, width=12, channels=1, categories=2,
                             filters=24, n_res=2, first_kernel=5)
    rows += _rows_for("binary-strokes(1bit)", bin_cfg,
                      binary_strokes(512, 12, 12, seed=0), horizon=6,
                      methods=("baseline", "zeros", "last", "fpi",
                               "forecast"), steps=steps)

    tex4_cfg = PixelCNNConfig(height=8, width=8, channels=3, categories=16,
                              filters=24, n_res=2, first_kernel=5)
    rows += _rows_for("textures(4bit)", tex4_cfg,
                      quantized_textures(512, 8, 8, 3, 16, seed=1),
                      horizon=2, methods=("baseline", "fpi", "forecast"),
                      steps=steps)

    tex8_cfg = PixelCNNConfig(height=8, width=8, channels=3, categories=256,
                              filters=24, n_res=2, first_kernel=5)
    rows += _rows_for("textures(8bit)", tex8_cfg,
                      quantized_textures(512, 8, 8, 3, 256, seed=2),
                      horizon=2, methods=("baseline", "fpi", "forecast"),
                      steps=steps)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
