"""Benchmark aggregator: one function per paper table + the beyond-paper
serving/roofline reports. Prints ``name,us_per_call,derived`` CSV.

``us_per_call`` = wall microseconds per ARM call / verify round.
``derived`` = the table's headline metric (ARM-call % vs ancestral, etc.).

Full run: ``PYTHONPATH=src python -m benchmarks.run``
(set REPRO_BENCH_FULL=1 for the longer-training variant).
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def _csv_rows_table(rows):
    out = []
    for r in rows:
        tbl = r.get("table", "?")
        if tbl in ("table1", "table2"):
            d = r["dataset"]
            name = f"{tbl}/{d}/b{r['batch']}/{r['method']}"
            # us per ARM call: time / (d * calls_pct/100)
            out.append((name, f"{r['time_s']*1e6:.0f}",
                        f"calls_pct={r['calls_pct']}+-{r['calls_std']}"))
        elif tbl == "table3":
            name = f"table3/{r['ablation'].replace(' ', '_')}"
            t = r.get("time_s")
            out.append((name, f"{(t or 0)*1e6:.0f}",
                        f"calls_pct={r['calls_pct']}"))
        elif tbl == "serving":
            if "scenario" in r:
                us = r["time_s"] * 1e6 / max(1, r["verify_rounds"])
                out.append((f"serving/{r['scenario']}", f"{us:.0f}",
                            f"calls_pct={r['calls_vs_ancestral_pct']};"
                            f"prefix_hit={r['prefix_hit_rate']};"
                            f"p50={r['latency_p50_s']}s;"
                            f"p95={r['latency_p95_s']}s"))
            elif "scheduler" in r:
                out.append(("serving/continuous_batching", "0",
                            f"calls_pct={r['calls_pct']}"))
            else:
                name = (f"serving/{r.get('stream','')}"
                        f"/window{r['window']}")
                us = r["time_s"] * 1e6 / max(1, r["verify_rounds"])
                out.append((name, f"{us:.0f}",
                            f"calls_pct={r['calls_pct']};"
                            f"accept={r['mean_accept']}"))
        elif tbl == "convergence":
            out.append(("figure6/convergence", "0",
                        f"arm_calls={r['arm_calls']}of{r['d']};"
                        f"left{r['left_mean']}<=right{r['right_mean']}"))
        elif tbl == "roofline":
            bt = r["bottlenecks"]
            out.append(("roofline/pairs", "0",
                        f"ok={r['pairs_ok']}of{r['pairs_total']};"
                        f"compute={bt['compute']};memory={bt['memory']};"
                        f"collective={bt['collective']}"))
    return out


def main() -> None:
    fast = os.environ.get("REPRO_BENCH_FULL", "0") != "1"
    print("name,us_per_call,derived")
    modules = [
        ("table1", "benchmarks.table1_image"),
        ("table2", "benchmarks.table2_latent"),
        ("table3", "benchmarks.table3_ablations"),
        ("figure6", "benchmarks.convergence"),
        ("serving", "benchmarks.serving_bench"),
        ("roofline", "benchmarks.roofline"),
    ]
    for name, modname in modules:
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run(fast=fast)
            for row in _csv_rows_table(rows):
                print(",".join(str(c) for c in row))
            print(f"# {name} done in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            print(f"{name}/FAILED,0,see_stderr")
            traceback.print_exc()


if __name__ == "__main__":
    main()
