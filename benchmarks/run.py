"""Benchmark aggregator: one function per paper table + the beyond-paper
serving/roofline reports. Prints ``name,us_per_call,derived`` CSV.

``us_per_call`` = wall microseconds per ARM call / verify round.
``derived`` = the table's headline metric (ARM-call % vs ancestral, etc.).

Full run: ``PYTHONPATH=src python -m benchmarks.run``
(set REPRO_BENCH_FULL=1 for the longer-training variant).

Serving rows are additionally written to
``benchmarks/artifacts/BENCH_serving.json`` — the perf-trajectory baseline
(per-round latency, HBM bytes moved, prefix hit rate, paged vs dense-gather)
that CI uploads from the tier-1 workflow. ``--serving-only`` produces just
that artifact from the training-free scenarios (paged-vs-dense sweep +
mixed traffic with untrained weights) so CI stays fast.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

ART = os.path.join(os.path.dirname(__file__), "artifacts")
BENCH_SERVING = os.path.join(ART, "BENCH_serving.json")


def _csv_rows_table(rows):
    out = []
    for r in rows:
        tbl = r.get("table", "?")
        if tbl in ("table1", "table2"):
            d = r["dataset"]
            name = f"{tbl}/{d}/b{r['batch']}/{r['method']}"
            # us per ARM call: time / (d * calls_pct/100)
            out.append((name, f"{r['time_s']*1e6:.0f}",
                        f"calls_pct={r['calls_pct']}+-{r['calls_std']}"))
        elif tbl == "table3":
            name = f"table3/{r['ablation'].replace(' ', '_')}"
            t = r.get("time_s")
            out.append((name, f"{(t or 0)*1e6:.0f}",
                        f"calls_pct={r['calls_pct']}"))
        elif tbl == "serving":
            if r.get("scenario") == "paged_vs_dense":
                out.append((f"serving/paged_vs_dense/cap{r['capacity']}",
                            f"{r['paged_wall_us_per_round']}",
                            f"dense_wall_us={r['dense_wall_us_per_round']};"
                            f"backend={r['backend']};"
                            f"paged_MB={r['paged_bytes']/1e6:.2f};"
                            f"dense_MB={r['dense_bytes']/1e6:.2f};"
                            f"traffic_ratio={r['traffic_ratio']}"))
            elif r.get("scenario") == "round_loop":
                out.append((f"serving/round_loop/b{r['batch']}",
                            f"{r['device_wall_us_per_token']}",
                            f"host_wall_us={r['host_wall_us_per_token']};"
                            f"disp_per_tok={r['device_dispatches_per_token']}"
                            f"(host={r['host_dispatches_per_token']});"
                            f"syncs_per_tok={r['device_syncs_per_token']}"
                            f"(host={r['host_syncs_per_token']});"
                            f"backend={r['backend']}"))
            elif r.get("scenario") == "fused_writeback":
                out.append(("serving/fused_writeback", "0",
                            f"pool_scatters={r['paged_pool_scatter_eqns']}"
                            f"(dense={r['dense_pool_scatter_eqns']},"
                            f"ref={r['reference_scatter_eqns_per_leaf']});"
                            f"pallas_calls={r['paged_pallas_calls']};"
                            f"dispatch_per_loop="
                            f"{r['paged_dispatches_per_loop']}"))
            elif r.get("scenario") == "donation":
                out.append((f"serving/donation/cap{r['capacity']}", "0",
                            f"aliased_MB={r['donated_alias_bytes']/1e6:.2f};"
                            f"pool_MB={r['pool_bytes']/1e6:.2f};"
                            f"donated_MB={r['donated_live_bytes']/1e6:.2f};"
                            f"copied_MB={r['copied_live_bytes']/1e6:.2f};"
                            f"backend={r['backend']}"))
            elif r.get("scenario") == "saturation":
                out.append((f"serving/saturation/{r['mode']}",
                            f"{r['time_s']*1e6:.0f}",
                            f"p95={r['latency_p95_s']}s;"
                            f"p50={r['latency_p50_s']}s;"
                            f"misses={r['deadline_misses']}"
                            f"(queued={r['deadline_missed_in_queue']});"
                            f"preempts={r['preemptions']};"
                            f"backend={r['backend']}"))
            elif r.get("scenario") == "saturation_mesh":
                out.append(("serving/saturation_mesh/data2", "0",
                            f"migrations={r['migrations_on']};"
                            f"blocks_moved={r['blocks_migrated_on']};"
                            f"admit_same_step={r['admitted_same_step_on']}"
                            f"(static={r['admitted_same_step_off']});"
                            f"bit_exact={r['bit_exact']};"
                            f"backend={r['backend']}"))
            elif r.get("scenario") == "host_tier":
                if r["mode"] == "tiered":
                    out.append((f"serving/host_tier/{r['mode']}",
                                f"{r['time_s']*1e6:.0f}",
                                f"prefix_hit_rate={r['prefix_hit_rate']};"
                                f"host_hit_rate={r['host_hit_rate']};"
                                f"h2d_overlap={r['h2d_overlap_frac']};"
                                f"staged={r['host_staged_blocks']};"
                                f"prefills={r['prefill_calls']};"
                                f"p95={r['latency_p95_s']}s;"
                                f"pool_scatters={r['pool_scatter_eqns']};"
                                f"backend={r['backend']}"))
                else:
                    out.append((f"serving/host_tier/{r['mode']}",
                                f"{r['time_s']*1e6:.0f}",
                                f"prefix_hit_rate={r['prefix_hit_rate']};"
                                f"dropped={r['blocks_dropped']};"
                                f"prefills={r['prefill_calls']};"
                                f"p95={r['latency_p95_s']}s;"
                                f"backend={r['backend']}"))
            elif r.get("scenario") == "recovery":
                extra = (f"disk_hits={r['disk_hits']};"
                         f"staged={r['host_staged_blocks']};"
                         f"pool_scatters={r['pool_scatter_eqns']};"
                         if r["mode"] == "warm" else "")
                out.append((f"serving/recovery/{r['mode']}",
                            f"{r['restart_time_s']*1e6:.0f}",
                            f"prefills={r['prefill_calls']};"
                            f"recovered={r['recovered_requests']}"
                            f"(parked={r['recovered_parked']});"
                            f"{extra}"
                            f"backend={r['backend']}"))
            elif r.get("scenario") == "continuous_batching":
                out.append((f"serving/continuous_batching/"
                            f"{r['mode']}/b{r['batch']}",
                            f"{r['time_s']*1e6:.0f}",
                            f"syncs_per_tok={r['syncs_per_token']};"
                            f"disp_per_tok={r['dispatches_per_token']};"
                            f"occ_backlog={r['occupancy_under_backlog']};"
                            f"adoptions={r['in_loop_adoptions']};"
                            f"staged={r['staged_sequences']};"
                            f"backend={r['backend']}"))
            elif r.get("scenario") == "mesh_serving":
                out.append((f"serving/mesh/data{r['data']}",
                            f"{r['mesh_wall_us_per_round']}",
                            f"single_wall_us={r['single_wall_us_per_round']};"
                            f"bit_exact={r['bit_exact']};"
                            f"backend={r['backend']}"))
            elif "scenario" in r:
                us = r["time_s"] * 1e6 / max(1, r["verify_rounds"])
                out.append((f"serving/{r['scenario']}", f"{us:.0f}",
                            f"calls_pct={r['calls_vs_ancestral_pct']};"
                            f"prefix_hit={r['prefix_hit_rate']};"
                            f"p50={r['latency_p50_s']}s;"
                            f"p95={r['latency_p95_s']}s"))
            elif "scheduler" in r:
                out.append(("serving/continuous_batching", "0",
                            f"calls_pct={r['calls_pct']}"))
            else:
                name = (f"serving/{r.get('stream','')}"
                        f"/window{r['window']}")
                us = r["time_s"] * 1e6 / max(1, r["verify_rounds"])
                out.append((name, f"{us:.0f}",
                            f"calls_pct={r['calls_pct']};"
                            f"accept={r['mean_accept']}"))
        elif tbl == "convergence":
            out.append(("figure6/convergence", "0",
                        f"arm_calls={r['arm_calls']}of{r['d']};"
                        f"left{r['left_mean']}<=right{r['right_mean']}"))
        elif tbl == "roofline":
            bt = r["bottlenecks"]
            out.append(("roofline/pairs", "0",
                        f"ok={r['pairs_ok']}of{r['pairs_total']};"
                        f"compute={bt['compute']};memory={bt['memory']};"
                        f"collective={bt['collective']}"))
        elif tbl == "roofline_paged":
            out.append((f"roofline/paged/{r['arch']}/cap{r['capacity']}",
                        f"{r['paged_s']*1e6:.0f}",
                        f"dense_us={r['dense_s']*1e6:.0f};"
                        f"traffic_ratio={r['traffic_ratio']}"))
    return out


def _write_bench_serving(rows) -> None:
    """Persist the serving perf baseline (acceptance artifact): every
    serving-table row, most importantly the paged-vs-dense sweep whose
    ``paged_bytes`` stays flat in capacity while ``dense_bytes`` grows."""
    os.makedirs(ART, exist_ok=True)
    serving = [r for r in rows if r.get("table") == "serving"]
    with open(BENCH_SERVING, "w") as f:
        json.dump({"rows": serving}, f, indent=2, sort_keys=True)
    print(f"# wrote {BENCH_SERVING} ({len(serving)} rows)", file=sys.stderr)


def serving_only() -> None:
    """Training-free serving baseline for CI: the paged-vs-dense capacity
    sweep, the donation live-bytes measurement, the mesh-serving equality
    row (when the host exposes >= 2 devices — the CI mesh job forces 8),
    the host-tier A/B (spill + H2D restage vs drop, with its hit-rate /
    prefill acceptance bar), the §15 continuous-batching A/B (staged vs
    host-admission — its per-token counters are pure event counts under
    fixed seeds, so ``perf_gate`` pins them against BENCH_baseline.json),
    plus one mixed-traffic run (prefix hit rate, latency percentiles) on
    untrained weights — no acceptance bar asserted for the latter."""
    import jax

    from benchmarks.serving_bench import (continuous_batching,
                                          donation_round_bytes,
                                          fused_writeback, host_tier,
                                          mesh_serving, mixed_traffic,
                                          paged_vs_dense, recovery,
                                          round_loop, saturation,
                                          saturation_mesh)
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM

    cfg = get_config("qwen3-1.7b", reduced=True)
    params = TransformerLM.init(jax.random.PRNGKey(0), cfg)
    rows = paged_vs_dense(cfg, params)
    rows.extend(round_loop(cfg, params))
    rows.extend(fused_writeback(cfg, params))
    rows.extend(donation_round_bytes(cfg, params))
    rows.extend(mesh_serving(cfg, params))
    rows.extend(saturation(cfg, params))
    rows.extend(saturation_mesh(cfg, params))
    rows.extend(host_tier(cfg, params))
    rows.extend(continuous_batching(cfg, params))
    rows.extend(recovery(cfg, params))
    rows.append(mixed_traffic(cfg, params, assert_bar=False))
    print("name,us_per_call,derived")
    for row in _csv_rows_table(rows):
        print(",".join(str(c) for c in row))
    _write_bench_serving(rows)


def main() -> None:
    fast = os.environ.get("REPRO_BENCH_FULL", "0") != "1"
    print("name,us_per_call,derived")
    modules = [
        ("table1", "benchmarks.table1_image"),
        ("table2", "benchmarks.table2_latent"),
        ("table3", "benchmarks.table3_ablations"),
        ("figure6", "benchmarks.convergence"),
        ("serving", "benchmarks.serving_bench"),
        ("roofline", "benchmarks.roofline"),
    ]
    for name, modname in modules:
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run(fast=fast)
            for row in _csv_rows_table(rows):
                print(",".join(str(c) for c in row))
            if name == "serving":
                _write_bench_serving(rows)
            print(f"# {name} done in {time.time()-t0:.0f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001
            print(f"{name}/FAILED,0,see_stderr")
            traceback.print_exc()


if __name__ == "__main__":
    if "--serving-only" in sys.argv:
        serving_only()
    else:
        main()
