"""Beyond-paper: predictive sampling as LLM serving (token domain).

Trains a tiny qwen3-family LM on repetitive motif streams (the
weakly-coupled regime where speculation pays; a strongly-coupled Markov
chain is the paper's §2.4 cascading-errors worst case — measured too),
then measures verify rounds vs ancestral decoding at several window sizes,
the learned-forecasting (MTP-style) head recovery on the hard stream, the
continuous-batching scheduler (the paper's future-work system), a
mixed-traffic scenario through the paged ``ServingEngine`` (short chat +
long completion requests sharing a system-prompt prefix) reporting prefix
cache hit rate and p50/p95 request latency, and the paged-attention
tentpole comparison: per-round wall time and HBM traffic for block-table
decode (``decode_window_paged``) vs the legacy dense gather/scatter round
as the cache capacity grows (DESIGN.md §9)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.data.synthetic import repetitive_tokens, synthetic_tokens
from repro.engine import ContinuousBatcher, PredictiveSampler, Request
from repro.models.losses import lm_loss
from repro.models.transformer import TransformerLM
from repro.serving import FaultPlan, ServingEngine, ServingTopology


def train_tiny_lm(cfg, steps=300, seed=0, gen=synthetic_tokens):
    data = gen(256, 64, cfg.vocab, seed=seed)
    params = TransformerLM.init(jax.random.PRNGKey(seed), cfg)
    opt = optim.adamw(2e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (l, m), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
        g = optim.zero_frozen(g)
        u, state2 = opt.update(g, state, params)
        return optim.apply_updates(params, u), state2, l

    rng = np.random.default_rng(seed)
    l = None
    for _ in range(steps):
        idx = rng.integers(0, data.shape[0], size=16)
        params, state, l = step(params, state, jnp.asarray(data[idx]))
    return params, float(l)


def run(fast: bool = True):
    import dataclasses

    steps = 300 if fast else 2000
    cfg = get_config("qwen3-1.7b", reduced=True)
    rows = []
    new_tokens = 48

    params_rep = None
    for stream, gen in (("repetitive", repetitive_tokens),
                        ("markov-hard", synthetic_tokens)):
        params, final_loss = train_tiny_lm(cfg, steps=steps, gen=gen)
        if stream == "repetitive":
            params_rep = params
        prompts = jnp.asarray(gen(4, 8, cfg.vocab, seed=99))
        toks_ref = None
        for W in (1, 8, 16):
            s = PredictiveSampler(cfg, params, window=W, max_len=96,
                                  eps_key=jax.random.PRNGKey(5))
            t0 = time.time()
            toks, st = s.generate(prompts, new_tokens)
            dt = time.time() - t0
            if W == 1:
                toks_ref = np.asarray(toks)
            else:
                assert (np.asarray(toks)[:, :40]
                        == toks_ref[:, :40]).all(), \
                    "serving exactness violated"
            rows.append({
                "table": "serving", "stream": stream, "window": W,
                "verify_rounds": st["rounds"],
                "calls_pct": round(100.0 * st["rounds"] / new_tokens, 1),
                "mean_accept": round(st["mean_accept"], 2),
                "time_s": round(dt, 3),
                "train_loss": round(final_loss, 3),
            })

    # learned forecasting heads (MTP correspondence) on the HARD stream:
    # conditioned only on the valid prefix, they predict ahead where FPI
    # suffers cascading errors (paper §2.4).
    cfg_fc = dataclasses.replace(cfg, forecast_horizon=4)
    params_fc, loss_fc = train_tiny_lm(cfg_fc, steps=steps,
                                       gen=synthetic_tokens)
    prompts = jnp.asarray(synthetic_tokens(4, 8, cfg.vocab, seed=99))
    s_fc = PredictiveSampler(cfg_fc, params_fc, window=8, max_len=96,
                             eps_key=jax.random.PRNGKey(5),
                             use_forecast_heads=True)
    toks, st = s_fc.generate(prompts, new_tokens)
    s_ref = PredictiveSampler(cfg_fc, params_fc, window=1, max_len=96,
                              eps_key=jax.random.PRNGKey(5))
    toks_ref, _ = s_ref.generate(prompts, new_tokens)
    assert (np.asarray(toks)[:, :40]
            == np.asarray(toks_ref)[:, :40]).all()
    rows.append({
        "table": "serving", "stream": "markov-hard+MTP-heads", "window": 8,
        "verify_rounds": st["rounds"],
        "calls_pct": round(100.0 * st["rounds"] / new_tokens, 1),
        "mean_accept": round(st["mean_accept"], 2),
        "time_s": 0.0, "train_loss": round(loss_fc, 3),
    })

    # scheduler: ragged lengths, continuous vs slowest-sample batching
    sampler = PredictiveSampler(cfg, params, window=8, max_len=128,
                                eps_key=jax.random.PRNGKey(6))
    batcher = ContinuousBatcher(sampler, batch=2)
    lens = [48, 12, 12, 12]
    rng = np.random.default_rng(1)
    for i, L in enumerate(lens):
        batcher.submit(Request(i, rng.integers(0, cfg.vocab, 4), L))
    done = batcher.run()
    rows.append({
        "table": "serving", "window": 8, "scheduler": "continuous",
        "requests": len(done), "total_new_tokens": sum(lens),
        "verify_rounds": int(np.asarray(batcher.state.rounds)),
        "calls_pct": round(100.0 * int(np.asarray(batcher.state.rounds))
                           / sum(lens), 1),
    })

    # mixed traffic through the paged ServingEngine: short chat + long
    # completion requests sharing a system-prompt prefix, on the repetitive
    # (weakly-coupled) stream where speculation pays. Reports the prefix
    # cache hit rate and request latency percentiles from the telemetry
    # module; asserts the acceptance bar (ARM calls/request strictly below
    # the ancestral baseline).
    rows.append(mixed_traffic(cfg, params_rep))

    # tentpole: block-table decode vs the dense gather/scatter round-trip
    rows.extend(paged_vs_dense(cfg, params_rep))

    # device-resident rounds: dispatches / host syncs per token vs the
    # host-driven baseline, and the single-dispatch fused-round gate
    rows.extend(round_loop(cfg, params_rep))
    rows.extend(fused_writeback(cfg, params_rep))

    # round-buffer donation: per-round live bytes with vs without
    rows.extend(donation_round_bytes(cfg, params_rep))

    # mesh serving (needs >= 2 devices; skipped on a single-device host)
    rows.extend(mesh_serving(cfg, params_rep))

    # device-resident continuous batching: in-loop slot adoption + staged
    # prompts + adaptive rounds_per_sync vs the k=1 host-admission path
    # (DESIGN.md §15) — this is the CI perf gate's data source
    rows.extend(continuous_batching(cfg, params_rep))

    # saturation: lookahead + preemption (+ mesh rebalancing) vs the
    # static head-of-line router on a skewed-length request mix
    rows.extend(saturation(cfg, params_rep))
    rows.extend(saturation_mesh(cfg, params_rep))

    # host cache tier: spilled prefixes re-admitted from the host arena
    # vs dropped outright (DESIGN.md §13)
    rows.extend(host_tier(cfg, params_rep))

    # fault isolation: scripted FaultPlan vs fault-free on identical
    # traffic — healthy requests bitwise equal, counters visible (§14)
    rows.extend(chaos(cfg, params_rep))

    # crash recovery: journal + checkpoint restart, cold vs warm (§16)
    rows.extend(recovery(cfg, params_rep))
    return rows


# ---------------------------------------------------------------------------
# Paged-attention tentpole: per-round traffic vs cache capacity
# ---------------------------------------------------------------------------

def _attn_bytes_per_token(cfg) -> int:
    """Bytes of paged attention-cache state per token position, summed over
    layers (GQA K+V; MLA latent + rope key), at the config dtype."""
    per = 0
    for mixer, _ in cfg.layer_specs():
        if mixer in ("attn", "local"):
            per += 2 * cfg.n_kv_heads * cfg.head_dim
        elif mixer == "mla":
            per += cfg.kv_lora_rank + cfg.qk_rope_dim
    return per * jnp.dtype(cfg.param_dtype).itemsize


def round_bytes_model(cfg, batch: int, capacity: int, used: int,
                      window: int) -> dict:
    """Analytic per-round HBM traffic (roofline-style, from shapes):

    * dense-gather round — materialize the full-capacity view (read pool +
      write view), attend over it, scatter the window blocks back:
      ~3x ``capacity`` positions per sequence regardless of fill.
    * paged round — the kernel streams each sequence's *used* blocks once
      (tail table entries alias the sink block; Pallas re-DMAs a block only
      when the index changes) and writes the W window rows in place.
    """
    ptb = _attn_bytes_per_token(cfg)
    dense = 3 * batch * capacity * ptb + 2 * batch * window * ptb
    paged = batch * (used + window) * ptb + 2 * batch * window * ptb
    return {"dense_bytes": int(dense), "paged_bytes": int(paged)}


def paged_vs_dense(cfg, params=None, capacities=(128, 512, 2048),
                   batch: int = 2, new_tokens: int = 12, seed: int = 11):
    """Paged block-table round vs the legacy dense gather/scatter round,
    identical traffic, growing cache capacity. Two kinds of columns:

    * ``*_wall_us_per_round`` — measured on this host (compile excluded by
      a warm-up drain). On a CPU backend the paged engine runs the
      gather-view *fallback* inside each attention layer, so wall time
      tracks capacity in BOTH columns there (the ``backend`` field records
      which case an artifact captured); on TPU the kernel streams blocks
      and the paged column is the flat one.
    * ``paged_bytes`` / ``dense_bytes`` — analytic per-round HBM traffic of
      the TPU kernel path vs the dense round-trip (``round_bytes_model``).
      These carry the tentpole claim deterministically: paged is flat in
      capacity, dense-gather linear (asserted below).
    """
    if params is None:
        params = TransformerLM.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompt_len = 8
    prompts = rng.integers(0, cfg.vocab, size=(2 * batch, prompt_len))
    rows = []
    for max_len in capacities:
        row = {"table": "serving", "scenario": "paged_vs_dense",
               "capacity": max_len, "batch": batch,
               "backend": jax.default_backend()}
        for mode in ("paged", "dense"):
            eng = ServingEngine(cfg, params, batch=batch, window_max=8,
                                max_len=max_len, block_size=16,
                                eps_key=jax.random.PRNGKey(3),
                                adaptive=False, prefix_cache=False,
                                paged_attention=(mode == "paged"))

            def drain(offset):
                for i in range(batch):
                    eng.submit(Request(uid=offset + i,
                                       prompt=prompts[offset + i],
                                       new_tokens=new_tokens))
                r0 = eng.metrics.rounds
                t0 = time.time()
                eng.run()
                return (time.time() - t0), eng.metrics.rounds - r0

            drain(0)                                 # compile + warm cache
            dt, nrounds = drain(batch)               # measured drain
            row[f"{mode}_wall_us_per_round"] = round(
                dt * 1e6 / max(1, nrounds))
        row.update(round_bytes_model(cfg, batch, max_len,
                                     used=prompt_len + new_tokens, window=8))
        row["traffic_ratio"] = round(row["dense_bytes"]
                                     / max(1, row["paged_bytes"]), 1)
        rows.append(row)
    # the paged traffic model must be flat in capacity; dense linear
    assert rows[-1]["paged_bytes"] == rows[0]["paged_bytes"]
    assert rows[-1]["dense_bytes"] > rows[0]["dense_bytes"]
    return rows


# ---------------------------------------------------------------------------
# Round-buffer donation: per-round live bytes (satellite, DESIGN.md §10)
# ---------------------------------------------------------------------------

def _round_memory(eng, W: int = 8) -> dict:
    """XLA memory analysis of the compiled verify round loop: live bytes
    (arguments + outputs + temps - donation aliasing) and the aliased
    bytes the donation actually established."""
    fn = eng._round_loop_fn(W, eng.rounds_per_sync)
    args = eng._round_args()
    ma = fn.lower(*args).compile().memory_analysis()
    if ma is None:                       # backend without memory analysis
        return {"live_bytes": -1, "alias_bytes": -1}
    live = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return {"live_bytes": live, "alias_bytes": int(ma.alias_size_in_bytes)}


def donation_round_bytes(cfg, params=None, batch: int = 2,
                         max_len: int = 1024, seed: int = 13):
    """Satellite measurement: donated vs copied round buffers.

    The donation contract is the assert: the round must alias at least the
    whole physical pool in place (``alias_bytes >= pool_bytes``) — without
    ``donate_argnums`` the old pool (dead on return) is a second full copy
    held across every round (``copied_live_bytes``). How much of the saving
    the backend realizes as peak-memory drop is backend-dependent: the CPU
    backend materializes the window scatter into a temp either way (the
    ``backend`` field records what an artifact measured); TPU updates the
    aliased pool in place."""
    if params is None:
        params = TransformerLM.init(jax.random.PRNGKey(seed), cfg)
    row = {"table": "serving", "scenario": "donation", "capacity": max_len,
           "batch": batch, "backend": jax.default_backend()}
    for donate in (True, False):
        eng = ServingEngine(cfg, params, batch=batch, window_max=8,
                            max_len=max_len, block_size=16,
                            eps_key=jax.random.PRNGKey(3), adaptive=False,
                            prefix_cache=False, donate=donate)
        mem = _round_memory(eng)
        key = "donated" if donate else "copied"
        row[f"{key}_live_bytes"] = mem["live_bytes"]
        row[f"{key}_alias_bytes"] = mem["alias_bytes"]
        if donate:
            row["pool_bytes"] = int(sum(
                x.nbytes for x in jax.tree.leaves(eng.paged)))
    row["saved_bytes"] = row["copied_live_bytes"] - row["donated_live_bytes"]
    if row["donated_alias_bytes"] >= 0:
        # the whole pool (+ per-slot state) must be donated in place; the
        # un-donated round must not alias anything
        assert row["donated_alias_bytes"] >= row["pool_bytes"], row
        assert row["copied_alias_bytes"] == 0, row
    return [row]


# ---------------------------------------------------------------------------
# Device-resident verify rounds (DESIGN.md §11): dispatches & host syncs
# ---------------------------------------------------------------------------

def round_loop(cfg, params=None, batches=(1, 8, 32), new_tokens: int = 6,
               rounds_per_sync: int = 4, seed: int = 21):
    """Host-driven (``rounds_per_sync=1``) vs device-resident
    (``rounds_per_sync=4``) verify rounds on identical traffic at several
    batch widths: device dispatches per generated token, host syncs per
    token (and per round), and wall-clock per token. The device-resident
    loop must be strictly below the host-driven baseline on both dispatch
    and sync counts — the PR 3 baseline is exactly the ``host`` column
    (one dispatch + one ``n`` pull per round). Tokens are asserted
    bit-identical between the two drive modes."""
    if params is None:
        params = TransformerLM.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    rows = []
    for B in batches:
        prompts = rng.integers(0, cfg.vocab, size=(2 * B, 4))
        row = {"table": "serving", "scenario": "round_loop", "batch": B,
               "new_tokens_per_req": new_tokens,
               "backend": jax.default_backend()}
        toks = {}
        for mode, k in (("host", 1), ("device", rounds_per_sync)):
            eng = ServingEngine(cfg, params, batch=B, window_max=4,
                                max_len=32, block_size=8,
                                eps_key=jax.random.PRNGKey(3),
                                adaptive=False, prefix_cache=False,
                                rounds_per_sync=k)

            def drain(offset):
                for i in range(B):
                    eng.submit(Request(uid=offset + i,
                                       prompt=prompts[offset + i],
                                       new_tokens=new_tokens))
                t0 = time.time()
                done = eng.run()
                return time.time() - t0, done

            drain(0)                             # compile + warm cache
            m0 = eng.export_metrics()
            dt, done = drain(B)
            m = eng.export_metrics()
            gen = B * new_tokens
            dispatches = m["device_dispatches"] - m0["device_dispatches"]
            syncs = m["host_syncs"] - m0["host_syncs"]
            nrounds = m["rounds"] - m0["rounds"]
            row[f"{mode}_dispatches_per_token"] = round(dispatches / gen, 3)
            row[f"{mode}_syncs_per_token"] = round(syncs / gen, 3)
            row[f"{mode}_syncs_per_round"] = round(syncs / max(1, nrounds),
                                                   3)
            row[f"{mode}_wall_us_per_token"] = round(dt * 1e6 / gen)
            row[f"{mode}_rounds"] = nrounds
            toks[mode] = {r.uid: r.result for r in done if r.uid >= B}
        for uid, t in toks["host"].items():
            assert (toks["device"][uid] == t).all(), \
                f"device-resident loop diverged from host-driven (uid {uid})"
        # the device-resident loop must beat the PR 3 (host-driven) baseline
        assert (row["device_dispatches_per_token"]
                < row["host_dispatches_per_token"]), row
        assert row["device_syncs_per_token"] < row["host_syncs_per_token"], \
            row
        assert row["device_syncs_per_round"] < 1.0 <= \
            row["host_syncs_per_round"], row
        rows.append(row)
    return rows


def fused_writeback(cfg, params=None, seed: int = 23):
    """Single-dispatch round gate (DESIGN.md §11): the verify round's jaxpr
    must contain ZERO pool-ranked scatter eqns — every physical-pool write
    (window K/V, MLA latents, the legacy dense round's span writeback) now
    happens inside a pallas_call as an input/output-aliased epilogue — and
    the whole k-round loop is ONE device program. The ``reference_scatter``
    column shows what the eliminated standalone ``write_window_paged``
    costs per layer: one pool-ranked scatter per K/V leaf per round.
    Dispatch counts here seed the §9 ``round_bytes_model`` calibration
    against measured per-dispatch latency on real hardware."""
    import jax.numpy as jnp

    from repro.analysis import Contract, check_engine_round, check_program
    from repro.kernels.paged_attention.ref import write_window_paged

    if params is None:
        params = TransformerLM.init(jax.random.PRNGKey(seed), cfg)
    row = {"table": "serving", "scenario": "fused_writeback",
           "backend": jax.default_backend()}
    for mode in ("paged", "dense"):
        eng = ServingEngine(cfg, params, batch=2, window_max=4, max_len=32,
                            block_size=4, eps_key=jax.random.PRNGKey(3),
                            adaptive=False, prefix_cache=False,
                            paged_attention=(mode == "paged"))
        rep = check_engine_round(eng)
        assert rep.ok, rep
        row[f"{mode}_pool_scatter_eqns"] = rep.metrics["pool_scatters"]
        row[f"{mode}_pallas_calls"] = rep.metrics["pallas_calls"]
        row[f"{mode}_dispatches_per_loop"] = 1    # one compiled program
    # what one eliminated pre-kernel scatter looks like, per K/V leaf: a
    # rule-less contract — this program is SUPPOSED to carry the scatter,
    # we only want the census numbers
    ref = check_program(
        write_window_paged,
        (jnp.zeros((9, 4, 2, 8)), jnp.zeros((2, 4, 2, 8)),
         jnp.zeros((2, 2), jnp.int32), jnp.zeros((2,), jnp.int32)),
        Contract("REFERENCE_WRITEBACK", []), label="write_window_paged")
    row["reference_scatter_eqns_per_leaf"] = ref.metrics["pool_scatters"]
    assert row["paged_pool_scatter_eqns"] == 0, row
    assert row["dense_pool_scatter_eqns"] == 0, row
    assert row["paged_pallas_calls"] >= 1, row
    assert row["reference_scatter_eqns_per_leaf"] == 1, row
    return [row]


# ---------------------------------------------------------------------------
# Mesh serving (DESIGN.md §10): sharded pools, routed admission
# ---------------------------------------------------------------------------

def mesh_serving(cfg, params, batch: int = 4, new_tokens: int = 12,
                 seed: int = 17):
    """Single-device vs data-sharded engine on identical traffic: asserts
    bitwise token equality (the topology exactness contract) and reports
    per-round wall time for each data size the host's devices allow."""
    import jax as _jax

    from repro.launch.mesh import make_host_mesh

    n_dev = len(_jax.devices())
    data_sizes = [d for d in (2, 4) if d <= n_dev and batch % d == 0]
    if not data_sizes:
        return []
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 10)))
               for _ in range(2 * batch)]

    def drain(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, new_tokens=new_tokens))
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        return {r.uid: r.result for r in done}, dt, eng

    kw = dict(batch=batch, window_max=8, max_len=128, block_size=16,
              eps_key=jax.random.PRNGKey(3), adaptive=False,
              prefix_cache=False)
    ref, dt_single, eng_s = drain(ServingEngine(cfg, params, **kw))
    rows = []
    for d in data_sizes:
        topo = ServingTopology(make_host_mesh(d, 1))
        got, dt, eng_m = drain(ServingEngine(cfg, params, topology=topo,
                                             **kw))
        for uid, toks in ref.items():
            assert (got[uid] == toks).all(), \
                f"mesh serving diverged from single device (uid {uid})"
        rows.append({
            "table": "serving", "scenario": "mesh_serving", "data": d,
            "batch": batch, "backend": jax.default_backend(),
            "bit_exact": True,
            "rounds": eng_m.metrics.rounds,
            "single_wall_us_per_round": round(
                dt_single * 1e6 / max(1, eng_s.metrics.rounds)),
            "mesh_wall_us_per_round": round(
                dt * 1e6 / max(1, eng_m.metrics.rounds)),
        })
    return rows


# ---------------------------------------------------------------------------
# Saturation: lookahead + preemption + rebalancing vs the static router
# (DESIGN.md §12)
# ---------------------------------------------------------------------------

def continuous_batching(cfg, params, batches=(8, 32), seed: int = 29,
                        assert_bar: bool = True):
    """Device-resident continuous batching (DESIGN.md §15): a deep queued
    backlog served by the staged engine (pre-staged prompts, in-loop slot
    adoption, adaptive rounds_per_sync) vs the host-admission baseline
    (``staging_slots=0``, whose ``k = 1``-under-backlog heuristic syncs
    every round). Both counters the perf gate pins — host syncs per token
    and device dispatches per token — are pure event counts, so the rows
    are deterministic across machines. Asserts the acceptance bar: both
    strictly below the baseline at every batch size, under-backlog
    occupancy saturated and within an adoption-latency allowance of the
    baseline's 1.0-by-construction (the whole-run weighted mean would
    instead rank engines by drain-tail composition noise), tokens bitwise
    identical per uid."""
    rows = []
    rng = np.random.default_rng(seed)
    for B in batches:
        n_req = 3 * B                               # 3 requests per slot
        prompts = [rng.integers(0, cfg.vocab, int(rng.integers(2, 7)))
                   for _ in range(n_req)]
        new_tok = [int(rng.integers(8, 17)) for _ in range(n_req)]
        results, mets = {}, {}
        for mode, slots in (("host-admission", 0), ("staged", 4)):
            eng = ServingEngine(cfg, params, batch=B, window_max=4,
                                max_len=64, eps_key=jax.random.PRNGKey(11),
                                block_size=4, adaptive=False,
                                rounds_per_sync=8, staging_slots=slots)
            for i, (p, nt) in enumerate(zip(prompts, new_tok)):
                eng.submit(Request(uid=i, prompt=p, new_tokens=nt))
            t0 = time.time()
            done = eng.run()
            dt = time.time() - t0
            assert len(done) == n_req, (mode, len(done))
            results[mode] = {r.uid: r.result for r in done}
            m = eng.export_metrics()
            mets[mode] = m
            rows.append({
                "table": "serving", "scenario": "continuous_batching",
                "mode": mode, "batch": B, "requests": n_req,
                "backend": jax.default_backend(),
                "tokens_generated": m["tokens_generated"],
                "host_syncs": m["host_syncs"],
                "device_dispatches": m["device_dispatches"],
                "syncs_per_token": round(m["syncs_per_token"], 5),
                "dispatches_per_token": round(m["dispatches_per_token"], 5),
                "rounds_per_sync": round(m["rounds_per_sync"], 3),
                "occupancy_under_backlog": round(
                    m["occupancy_under_backlog"], 4),
                "occupancy_weighted": round(m["occupancy_weighted"], 4),
                "mean_batch_occupancy": round(m["mean_batch_occupancy"], 4),
                "in_loop_adoptions": m["in_loop_adoptions"],
                "staged_sequences": m["staged_sequences"],
                "staging_occupancy": round(m["staging_occupancy"], 4),
                "idle_row_rounds": m["idle_row_rounds"],
                "rounds_per_sync_final": m["rounds_per_sync_final"],
                "time_s": round(dt, 3),
            })
        for uid, toks in results["host-admission"].items():
            assert (results["staged"][uid] == toks).all(), \
                f"staging changed tokens (uid {uid})"
        if assert_bar:
            on, off = mets["staged"], mets["host-admission"]
            assert on["syncs_per_token"] < off["syncs_per_token"], (
                B, on["syncs_per_token"], off["syncs_per_token"])
            assert on["dispatches_per_token"] < off["dispatches_per_token"], (
                B, on["dispatches_per_token"], off["dispatches_per_token"])
            # occupancy bar, measured where it means something: loops
            # dispatched WITH backlog. The k=1 baseline is 1.0 there by
            # construction (it syncs every round; refill is instant), so
            # "no worse" carries an adoption-latency allowance: a freed
            # row may idle <= 1 round before the adoption scan or the
            # starvation exit reacts, i.e. idle fraction <= frees/(B*k)
            # — up to ~5% at B=8, shrinking with batch. The real
            # requirement is that occupancy stays SATURATED instead of
            # cratering for k rounds per freed row, which is what an
            # adoption-less long loop does.
            assert on["occupancy_under_backlog"] >= 0.95, (
                B, on["occupancy_under_backlog"])
            assert (on["occupancy_under_backlog"]
                    >= off["occupancy_under_backlog"] - 0.05), (
                B, on["occupancy_under_backlog"],
                off["occupancy_under_backlog"])
            assert on["in_loop_adoptions"] > 0, B
    return rows


def saturation(cfg, params, n_small: int = 40, seed: int = 31,
               assert_bar: bool = True):
    """Skewed-length mix under saturation: two oversized requests at the
    queue head (only one fits the pool at a time) ahead of ``n_small``
    tiny high-priority requests. The static router (``lookahead=1``, no
    preemption — the old ``break``-on-head admission) head-of-line blocks
    every small request behind the unroutable head until the first big one
    drains; the saturation-safe scheduler admits them immediately
    (lookahead) and parks the low-priority big request (preemption),
    resuming it exactly later. Asserts the acceptance bar: p95 latency and
    deadline misses strictly below the static router, tokens bitwise
    identical (scheduling may differ; tokens cannot)."""
    BIG, SMALL = 256, 1
    bs = 4
    kw = dict(batch=4, window_max=4, max_len=260,
              eps_key=jax.random.PRNGKey(3),
              block_size=bs, adaptive=False, prefix_cache=False,
              # pool: one big request (66 blocks) pins the shard — a small
              # (2 blocks) only fits after lookahead evicts/bypasses it
              num_blocks=68)
    rng = np.random.default_rng(seed)
    big_prompts = [rng.integers(0, cfg.vocab, 4) for _ in range(2)]
    small_prompts = [rng.integers(0, cfg.vocab, 2) for _ in range(n_small)]

    def make(mode):
        if mode == "static":
            return ServingEngine(cfg, params, lookahead=1, preempt=False,
                                 rebalance=False, **kw)
        return ServingEngine(cfg, params, lookahead=64, max_head_bypass=64,
                             preempt=True, **kw)

    def drain_saturated(eng, deadline):
        for i, p in enumerate(big_prompts):
            eng.submit(Request(uid=i, prompt=p, new_tokens=BIG, priority=1))
        eng.step()                       # the first big request is running
        for i, p in enumerate(small_prompts):
            eng.submit(Request(uid=10 + i, prompt=p, new_tokens=SMALL,
                               priority=0, deadline=deadline))
        t0 = time.time()
        done = eng.run()
        return done, time.time() - t0

    # calibrate: warm one engine (compile), then time one big request solo
    # on it — small deadlines are set to 0.8x that, so they are blown
    # exactly when a small request sits behind a big one (the saturated
    # big runs with k=1 yields, i.e. strictly slower than this measure)
    calib = make("static")
    for i, p in enumerate(small_prompts[:4]):
        calib.submit(Request(uid=900 + i, prompt=p, new_tokens=SMALL))
    calib.submit(Request(uid=998, prompt=big_prompts[0], new_tokens=BIG))
    calib.run()
    calib.submit(Request(uid=999, prompt=big_prompts[0], new_tokens=BIG))
    t0 = time.time()
    calib.run()
    t_big = time.time() - t0
    deadline = 0.8 * t_big

    rows, results = [], {}
    for mode in ("static", "scheduled"):
        eng = make(mode)
        # warm this engine's jit cache so the measured drain is compile-free
        for i, p in enumerate(small_prompts[:4]):
            eng.submit(Request(uid=900 + i, prompt=p, new_tokens=SMALL))
        eng.submit(Request(uid=999, prompt=big_prompts[1], new_tokens=BIG))
        eng.run()
        eng.metrics = type(eng.metrics)()     # measured window only
        done, dt = drain_saturated(eng, deadline)
        m = eng.export_metrics()
        results[mode] = {r.uid: r.result for r in done if r.uid < 900}
        rows.append({
            "table": "serving", "scenario": "saturation", "mode": mode,
            "backend": jax.default_backend(),
            "requests": 2 + n_small, "deadline_s": round(deadline, 4),
            "time_s": round(dt, 3),
            "latency_p50_s": round(m["latency_p50_s"], 4),
            "latency_p95_s": round(m["latency_p95_s"], 4),
            "deadline_misses": m["deadline_miss_count"],
            "deadline_missed_in_queue": m["deadline_missed_in_queue"],
            "preemptions": m["preemptions"],
            "resumes": m["resumes"],
            "head_bypass_admissions": m["head_bypass_admissions"],
        })
    by_mode = {r["mode"]: r for r in rows}
    for uid, toks in results["static"].items():
        assert (results["scheduled"][uid] == toks).all(), \
            f"scheduling changed tokens (uid {uid})"
    if assert_bar:
        on, off = by_mode["scheduled"], by_mode["static"]
        assert on["latency_p95_s"] < off["latency_p95_s"], (on, off)
        assert on["deadline_misses"] < off["deadline_misses"], (on, off)
        assert on["preemptions"] >= 1, on
    return rows


def saturation_mesh(cfg, params, seed: int = 33):
    """Shard rebalancing under the mesh: a long request pins shard 0's
    sub-pool while shard 1 holds two shorter ones; a mid-size arrival fits
    neither shard directly (shard 0: free slot, no blocks; shard 1:
    blocks, no slot). With rebalancing ON a resident migrates off shard 1
    into shard 0's remaining headroom and the arrival admits immediately;
    the static router leaves it queued until a resident finishes. Tokens
    must be bitwise identical either way; no wall-clock assertions (the
    contract here is structural: a migration happened and admission
    succeeded in the same step)."""
    import jax as _jax

    from repro.launch.mesh import make_host_mesh

    if len(_jax.devices()) < 2:
        return []
    rng = np.random.default_rng(seed)
    # per-shard pool: 16 usable blocks. big reserves 12 (pins shard 0,
    # leaving headroom 4); smalls reserve 4 each (pool routing sends both
    # to shard 1); the mid arrival reserves 6 — too big for shard 0's
    # leftover, no slot on shard 1. Rebalancing must migrate one small
    # (reservation 4 <= shard 0's headroom) to admit it; all of this is
    # decided inside ONE admission pass, before any verify round runs, so
    # the ON/OFF contrast is deterministic.
    prompts = {0: rng.integers(0, cfg.vocab, 4),    # big: 12 blocks
               1: rng.integers(0, cfg.vocab, 2),    # small: 4 blocks
               2: rng.integers(0, cfg.vocab, 2),    # small: 4 blocks
               3: rng.integers(0, cfg.vocab, 4)}    # mid:   6 blocks
    new = {0: 40, 1: 8, 2: 8, 3: 16}
    kw = dict(batch=4, window_max=4, max_len=48, block_size=4,
              eps_key=jax.random.PRNGKey(3), adaptive=False,
              prefix_cache=False, num_blocks=17)

    def drain(rebalance):
        topo = ServingTopology(make_host_mesh(2, 1))
        eng = ServingEngine(cfg, params, topology=topo,
                            rebalance=rebalance, **kw)
        for uid in (0, 1, 2, 3):
            eng.submit(Request(uid=uid, prompt=prompts[uid],
                               new_tokens=new[uid]))
        eng.step()
        admitted_now = len(eng.queue) == 0
        done = eng.run()
        return ({r.uid: r.result for r in done}, admitted_now,
                eng.export_metrics())

    got_on, admitted_on, m_on = drain(True)
    got_off, admitted_off, m_off = drain(False)
    for uid, toks in got_off.items():
        assert (got_on[uid] == toks).all(), \
            f"rebalancing changed tokens (uid {uid})"
    assert m_on["migrations"] >= 1, m_on
    assert admitted_on and not admitted_off, (admitted_on, admitted_off)
    return [{
        "table": "serving", "scenario": "saturation_mesh", "data": 2,
        "backend": jax.default_backend(), "bit_exact": True,
        "migrations_on": m_on["migrations"],
        "blocks_migrated_on": m_on["blocks_migrated"],
        "admitted_same_step_on": admitted_on,
        "admitted_same_step_off": admitted_off,
        "queue_wait_p95_on_s": round(m_on["queue_wait_p95_s"], 4),
        "queue_wait_p95_off_s": round(m_off["queue_wait_p95_s"], 4),
    }]


# ---------------------------------------------------------------------------
# Host cache tier (DESIGN.md §13): spilled prefixes re-admitted from host
# ---------------------------------------------------------------------------

def host_tier(cfg, params, families: int = 4, blocks_per_prefix: int = 4,
              passes: int = 3, seed: int = 41, assert_bar: bool = True):
    """Repetitive-prefix stream whose device pool holds ~25% of the prefix
    working set: ``families`` shared prefixes of ``blocks_per_prefix`` full
    blocks cycle round-robin, so by the time a family recurs its blocks
    have been evicted from the device pool. Without the tier those
    evictions drop the contents (every recurrence re-prefills); with it
    they spill D2H and the recurrence H2D-stages them back.

    Acceptance bar (asserted): the tiered engine sees a strictly higher
    prefix-hit rate and strictly fewer prefill calls than the no-tier
    engine on identical traffic, with bitwise-identical tokens. Also
    reports the host hit rate, the H2D overlap fraction, p95 latency for
    both modes, and re-checks the round-loop HLO gate (zero pool-ranked
    scatter eqns) on the TIERED engine — the tier must stay off the verify
    hot path."""
    from repro.analysis import check_engine_round

    bs = 4
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab, blocks_per_prefix * bs)
                for _ in range(families)]
    # prefix working set: families * blocks_per_prefix = 16 blocks; pool
    # below keeps ~4 cached-free survivors between admissions (~25%)
    kw = dict(batch=1, window_max=4, max_len=48, block_size=bs,
              eps_key=jax.random.PRNGKey(3), adaptive=False,
              num_blocks=2 + blocks_per_prefix + 4)

    def drain(eng):
        uid = 0
        for _ in range(passes):
            for fam, pre in enumerate(prefixes):
                eng.submit(Request(
                    uid=uid,
                    prompt=np.concatenate([pre, [1 + uid % cfg.vocab]]),
                    new_tokens=8))
                uid += 1
        t0 = time.time()
        done = eng.run()
        return done, time.time() - t0

    rows, results, hits = [], {}, {}
    for mode, mb in (("tiered", None), ("no-tier", 0)):
        eng = ServingEngine(cfg, params, host_cache_mb=mb, **kw)
        done, dt = drain(eng)
        m = eng.export_metrics()
        results[mode] = {r.uid: r.result for r in done}
        hits[mode] = sum(r.prefix_hit_blocks for r in done)
        row = {"table": "serving", "scenario": "host_tier", "mode": mode,
               "backend": jax.default_backend(),
               "requests": len(done), "time_s": round(dt, 3),
               "prefix_hit_blocks": hits[mode],
               "prefix_hit_rate": round(
                   hits[mode] / (len(done) * blocks_per_prefix), 3),
               "prefill_calls": m["prefill_calls"],
               "latency_p95_s": round(m["latency_p95_s"], 4),
               "blocks_spilled": m["blocks_spilled"],
               "blocks_dropped": m["blocks_dropped"]}
        if mode == "tiered":
            row.update({
                "host_hit_rate": round(
                    m["host_hits"] / max(1, m["host_hits"]
                                         + m["host_misses"]), 3),
                "host_staged_blocks": m["host_staged_blocks"],
                "h2d_overlap_frac": round(m["h2d_overlap_frac"], 3),
                "host_bytes_resident": m["host_bytes_resident"]})
            # hot-path gate: the tier is host-side only — the §17 round
            # contract (incl. zero pool-ranked scatters) still holds
            rep = check_engine_round(eng)
            assert rep.ok, rep
            row["pool_scatter_eqns"] = rep.metrics["pool_scatters"]
        rows.append(row)
    for uid, toks in results["no-tier"].items():
        assert (results["tiered"][uid] == toks).all(), \
            f"host tier changed tokens (uid {uid})"
    if assert_bar:
        by = {r["mode"]: r for r in rows}
        assert hits["tiered"] > hits["no-tier"], (hits, rows)
        assert (by["tiered"]["prefill_calls"]
                < by["no-tier"]["prefill_calls"]), rows
        assert by["tiered"]["host_staged_blocks"] >= 1, rows
        assert by["tiered"]["pool_scatter_eqns"] == 0, rows
    return rows


def recovery(cfg, params, seed: int = 53, assert_bar: bool = True):
    """Crash/restart scenario (DESIGN.md §16): cold vs warm restart cost.

    A batch=1 engine admits one long low-priority request, parks it under
    three high-priority arrivals, and is then abandoned mid-run without
    ``close()`` — exactly the state a SIGKILLed process leaves (the journal
    and per-step checkpoints are already durable; nothing else is). A
    fresh engine over the same durable directory ``restore()``s and drains
    the remaining work. Two modes:

    * ``cold`` — ``disk_tier=False``: the journal re-admits everything,
      but every recovered prompt block must re-prefill from scratch.
    * ``warm`` — disk tier on: the parked snapshot's chain keys were
      ``flush_to_disk``-ed at the crash-preceding checkpoint, so the cold
      resume pulls its prefix blocks back through the arena/disk
      fall-through instead of recomputing them.

    Acceptance bar (asserted): both modes bitwise-match the uninterrupted
    reference; the warm restart pays strictly fewer prefill chunks than
    the cold one and serves >= 1 block from disk; the restored engine's
    round loop still compiles with zero pool-ranked scatters (the
    durability layer is host-side only)."""
    import shutil
    import tempfile

    from repro.analysis import check_engine_round

    kw = dict(batch=1, window_max=4, max_len=64, block_size=4,
              eps_key=jax.random.PRNGKey(11), adaptive=False,
              preempt_floor=1.0)
    rng = np.random.default_rng(seed)
    low_prompt = rng.integers(0, cfg.vocab, size=24)
    highs = [rng.integers(0, cfg.vocab, size=6) for _ in range(3)]

    def make():
        out = [Request(uid=0, prompt=low_prompt.copy(), new_tokens=10,
                       priority=5)]
        out += [Request(uid=1 + i, prompt=p.copy(), new_tokens=6,
                        priority=0) for i, p in enumerate(highs)]
        return out

    def drive_to_crash(eng):
        """Admit the low-pri request, pile on the high-pri ones, and stop
        one sync boundary after the preemption lands — the checkpoint now
        holds the parked snapshot."""
        reqs = make()
        eng.submit(reqs[0])
        eng.step()
        for r in reqs[1:]:
            eng.submit(r)
        steps = 0
        while eng.metrics.preemptions == 0 and steps < 50:
            eng.step()
            steps += 1
        eng.step()
        assert 0 in eng.parked, "workload failed to park the long request"

    # uninterrupted reference (volatile) on identical traffic
    ref_eng = ServingEngine(cfg, params, **kw)
    reqs = make()
    ref_eng.submit(reqs[0])
    ref_eng.step()
    for r in reqs[1:]:
        ref_eng.submit(r)
    ref = {r.uid: r.result for r in ref_eng.run() if r.result is not None}

    rows, results = [], {}
    for mode, disk in (("warm", True), ("cold", False)):
        ddir = tempfile.mkdtemp(prefix=f"repro-recovery-{mode}-")
        try:
            e1 = ServingEngine(cfg, params, durable_dir=ddir,
                               disk_tier=disk, **kw)
            drive_to_crash(e1)       # abandoned: no close(), no final sync
            e2 = ServingEngine(cfg, params, durable_dir=ddir,
                               disk_tier=disk, **kw)
            t0 = time.time()
            recovered = e2.restore()
            done = e2.run()
            dt = time.time() - t0
            m = e2.export_metrics()
            # pre-crash deliveries re-arrive via journal re-delivery, so
            # e2.done alone is the complete result set
            results[mode] = {r.uid: r.result for r in done
                             if r.result is not None}
            row = {"table": "serving", "scenario": "recovery", "mode": mode,
                   "backend": jax.default_backend(),
                   "requests": len(results[mode]),
                   "restart_time_s": round(dt, 3),
                   "recovered_requests": recovered,
                   "recovered_parked": m["recovered_parked"],
                   "prefill_calls": m["prefill_calls"],
                   "host_staged_blocks": m["host_staged_blocks"],
                   "disk_hits": m["disk_hits"],
                   "disk_promotes": m["disk_promotes"],
                   "resume_recomputes": m["resume_recomputes"],
                   "checkpoints_written": m["checkpoints_written"],
                   "journal_appends": m["journal_appends"]}
            if mode == "warm":
                # hot-path gate on the RESTORED engine: durability stays
                # host-side, the §17 round contract still holds
                rep = check_engine_round(e2)
                assert rep.ok, rep
                row["pool_scatter_eqns"] = rep.metrics["pool_scatters"]
            rows.append(row)
        finally:
            shutil.rmtree(ddir, ignore_errors=True)
    for mode, res in results.items():
        assert set(res) == set(ref), (mode, sorted(res), sorted(ref))
        for uid, toks in ref.items():
            assert (res[uid] == toks).all(), \
                f"{mode} restart changed tokens (uid {uid})"
    if assert_bar:
        by = {r["mode"]: r for r in rows}
        assert (by["warm"]["prefill_calls"]
                < by["cold"]["prefill_calls"]), rows
        assert by["warm"]["disk_hits"] >= 1, rows
        assert by["warm"]["recovered_parked"] >= 1, rows
        assert by["warm"]["pool_scatter_eqns"] == 0, rows
    return rows


def chaos(cfg, params, seed: int = 47, assert_bar: bool = True):
    """Fault-isolation scenario (DESIGN.md §14): identical traffic through
    a fault-free engine and one under a scripted :class:`FaultPlan` — an
    injected block-allocation failure at the first admission, arena
    corruption + put rejections + staging drops at seeded rates, one
    NaN-poisoned noise stream, one mid-flight cancel — with a retry budget
    of 1.

    Acceptance bar (asserted): every healthy request (neither poisoned nor
    cancelled) emits tokens bitwise equal to the fault-free run; the
    poisoned request recovers on a fresh noise stream; nothing fails
    permanently; the §14 failure counters (``requests_failed``,
    ``requests_cancelled``, ``checksum_failures``, ``tier_tripped``,
    ``retries``) are published in the rows."""
    POISONED, CANCELLED = 2, 4
    kw = dict(batch=2, window_max=4, max_len=64, block_size=4,
              eps_key=jax.random.PRNGKey(3), adaptive=False,
              host_cache_mb=8)

    def traffic(eng, cancel_uid=None):
        rng = np.random.default_rng(seed)
        for i in range(5):
            eng.submit(Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(4, 10))),
                new_tokens=int(rng.integers(10, 16))))
        eng.step()
        # park one running slot so resume crosses the (corruptible) arena
        occ = [b for b in range(eng.B) if eng.slots[b] is not None]
        eng.preempt_slot(occ[0])
        if cancel_uid is not None:
            assert eng.cancel(cancel_uid)
        t0 = time.time()
        done = eng.run()
        return done, time.time() - t0

    plan = FaultPlan(schedule={"alloc": (0,)},
                     rates={"arena_corrupt": 0.75, "arena_put": 0.25,
                            "stage_drop": 0.5},
                     poison_streams=(POISONED,), seed=seed)
    rows, results = [], {}
    for mode, faults, cancel_uid in (("fault-free", FaultPlan(), None),
                                     ("chaos", plan, CANCELLED)):
        eng = ServingEngine(cfg, params, faults=faults, request_retries=1,
                            **kw)
        done, dt = traffic(eng, cancel_uid)
        m = eng.export_metrics()
        results[mode] = {r.uid: r for r in done}
        rows.append({
            "table": "serving", "scenario": "chaos", "mode": mode,
            "backend": jax.default_backend(),
            "requests": len(done),
            "completed_ok": sum(1 for r in done if r.ok),
            "time_s": round(dt, 3),
            "faults_injected": m["faults_injected"],
            "requests_failed": m["requests_failed"],
            "requests_cancelled": m["requests_cancelled"],
            "retries": m["retries"],
            "checksum_failures": m["checksum_failures"],
            "tier_tripped": m["tier_tripped"],
            "staging_errors": m["staging_errors"],
            "resume_recomputes": m["resume_recomputes"],
            "preemptions": m["preemptions"]})
    # §14 exactness: healthy requests are bitwise those of the clean run
    for uid, ref in results["fault-free"].items():
        if uid in (POISONED, CANCELLED):
            continue
        got = results["chaos"][uid]
        assert got.ok and ref.ok, (uid, got.error, ref.error)
        assert (got.result == ref.result).all(), \
            f"chaos changed healthy request {uid}'s tokens"
    if assert_bar:
        by = {r["mode"]: r for r in rows}
        c = by["chaos"]
        assert by["fault-free"]["faults_injected"] == 0, rows
        assert c["faults_injected"] >= 2, rows
        # alloc replay (same stream) + quarantine requeue (fresh stream)
        assert c["retries"] >= 2, rows
        assert c["requests_cancelled"] == 1, rows
        assert c["requests_failed"] == 0, rows      # retry budget recovered
        assert results["chaos"][POISONED].ok, \
            results["chaos"][POISONED].error
        assert c["checksum_failures"] >= 1, rows
    return rows


def mixed_traffic(cfg, params, batch: int = 2, seed: int = 7,
                  assert_bar: bool = True):
    """``assert_bar=False`` skips the acceptance assertions (used by the
    training-free ``run.py --serving-only`` CI baseline, where untrained
    weights make the ancestral-calls bar meaningless)."""
    engine = ServingEngine(cfg, params, batch=batch, window_max=16,
                           max_len=128, eps_key=jax.random.PRNGKey(8),
                           block_size=8, adaptive=True)
    rng = np.random.default_rng(seed)
    system_prompt = repetitive_tokens(1, 24, cfg.vocab, seed=seed)[0]
    uid = 0
    for _ in range(3):                      # interleaved arrival pattern
        for kind, new in (("chat", 8), ("chat", 8), ("completion", 48)):
            tail = rng.integers(0, cfg.vocab, size=int(rng.integers(2, 6)))
            engine.submit(Request(
                uid=uid, prompt=np.concatenate([system_prompt, tail]),
                new_tokens=new, priority=0 if kind == "chat" else 1))
            uid += 1
    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    m = engine.export_metrics()
    assert len(done) == uid
    if assert_bar:
        # acceptance bar: strictly below ancestral cost on the repetitive
        # stream
        assert m["arm_calls_vs_ancestral"] < 1.0, m
        assert m["prefix_hit_rate"] > 0.0, m
    return {
        "table": "serving", "scenario": "mixed-traffic",
        "requests": len(done), "time_s": round(dt, 3),
        "verify_rounds": m["rounds"],
        "prefill_calls": m["prefill_calls"],
        "calls_vs_ancestral_pct": round(100.0 * m["arm_calls_vs_ancestral"],
                                        1),
        "prefix_hit_rate": round(m["prefix_hit_rate"], 3),
        "latency_p50_s": round(m["latency_p50_s"], 4),
        "latency_p95_s": round(m["latency_p95_s"], 4),
        "mean_window": round(m["mean_window"], 2),
        "mean_occupancy": round(m["mean_batch_occupancy"], 2),
    }


if __name__ == "__main__":
    for r in run():
        print(r)
