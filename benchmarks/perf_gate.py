"""CI perf gate for the device-resident continuous-batching scenario
(DESIGN.md §15).

Compares a fresh ``serving_bench.continuous_batching`` run (the
``continuous_batching`` rows of ``benchmarks/artifacts/BENCH_serving.json``,
produced by ``python -m benchmarks.run --serving-only``) against the pinned
``BENCH_baseline.json`` at the repo root and fails on regression. The gated
counters are pure event counts under fixed seeds on the CPU backend —
host syncs per token, device dispatches per token, under-backlog occupancy,
in-loop adoptions — so they are deterministic across machines and a small
tolerance only absorbs library-version scheduling jitter, not noise.

Gates per (mode, batch) row:

* ``syncs_per_token``      fresh <= baseline * (1 + REL_TOL)
* ``dispatches_per_token`` fresh <= baseline * (1 + REL_TOL)
* ``occupancy_under_backlog`` fresh >= baseline - ABS_TOL
* staged rows keep ``in_loop_adoptions > 0``

Plus the cross-mode §15 bar re-asserted on the fresh rows: the staged
engine stays strictly below host-admission on both per-token counters at
every batch size.

The §16 ``recovery`` rows are gated too — crash-restart economics must not
silently rot:

* per mode (warm/cold): ``prefill_calls`` fresh <= pinned (re-prefilling
  more chunks after restart is a durability regression), and the
  deterministic recovery census (``recovered_requests``,
  ``recovered_parked``) stays exactly at the pin;
* warm keeps ``disk_hits >= 1`` (the disk tier actually served blocks)
  and ``pool_scatter_eqns == 0`` (the restored engine's round loop stays
  scatter-free);
* cross-mode: warm ``prefill_calls`` strictly below cold — the whole
  point of the durable tier.

Usage::

    PYTHONPATH=src python -m benchmarks.run --serving-only
    python -m benchmarks.perf_gate            # exits 1 on regression

Refreshing the pin after an intentional perf change::

    python -m benchmarks.perf_gate --update   # rewrites BENCH_baseline.json
"""
from __future__ import annotations

import json
import os
import sys

REL_TOL = 0.05    # relative slack on per-token event counts
ABS_TOL = 0.02    # absolute slack on occupancy fractions

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "BENCH_baseline.json")
FRESH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "artifacts", "BENCH_serving.json")

KEYS = ("syncs_per_token", "dispatches_per_token",
        "occupancy_under_backlog", "in_loop_adoptions")
REC_KEYS = ("prefill_calls", "recovered_requests", "recovered_parked",
            "disk_hits")


def _load_rows(path: str) -> list:
    with open(path) as f:
        data = json.load(f)
    return data["rows"] if isinstance(data, dict) else data


def _cb_rows(rows: list) -> dict:
    out = {}
    for r in rows:
        if r.get("scenario") != "continuous_batching":
            continue
        out[(r["mode"], r["batch"])] = {k: r[k] for k in KEYS}
    return out


def _recovery_rows(rows: list) -> dict:
    out = {}
    for r in rows:
        if r.get("scenario") != "recovery":
            continue
        keep = {k: r[k] for k in REC_KEYS}
        if "pool_scatter_eqns" in r:
            keep["pool_scatter_eqns"] = r["pool_scatter_eqns"]
        out[r["mode"]] = keep
    return out


def check(baseline: dict, fresh: dict) -> list[str]:
    errs = []
    for key, base in sorted(baseline.items()):
        mode, batch = key
        got = fresh.get(key)
        if got is None:
            errs.append(f"missing fresh row for mode={mode} batch={batch}")
            continue
        for k in ("syncs_per_token", "dispatches_per_token"):
            if got[k] > base[k] * (1 + REL_TOL):
                errs.append(
                    f"{mode}/B{batch} {k} regressed: "
                    f"{got[k]:.5f} > {base[k]:.5f} * {1 + REL_TOL}")
        k = "occupancy_under_backlog"
        if got[k] < base[k] - ABS_TOL:
            errs.append(f"{mode}/B{batch} {k} regressed: "
                        f"{got[k]:.4f} < {base[k]:.4f} - {ABS_TOL}")
        if mode == "staged" and got["in_loop_adoptions"] <= 0:
            errs.append(f"staged/B{batch} lost in-loop adoption "
                        f"(adoptions={got['in_loop_adoptions']})")
    # the §15 cross-mode bar, independent of the pin
    batches = sorted({b for (_, b) in fresh})
    for b in batches:
        on, off = fresh.get(("staged", b)), fresh.get(("host-admission", b))
        if not on or not off:
            continue
        for k in ("syncs_per_token", "dispatches_per_token"):
            if not on[k] < off[k]:
                errs.append(f"B{b} staged {k} not below host-admission: "
                            f"{on[k]:.5f} vs {off[k]:.5f}")
    return errs


def check_recovery(baseline: dict, fresh: dict) -> list[str]:
    errs = []
    for mode, base in sorted(baseline.items()):
        got = fresh.get(mode)
        if got is None:
            errs.append(f"missing fresh recovery row for mode={mode}")
            continue
        if got["prefill_calls"] > base["prefill_calls"]:
            errs.append(
                f"recovery/{mode} prefill_calls regressed: "
                f"{got['prefill_calls']} > pinned {base['prefill_calls']}")
        for k in ("recovered_requests", "recovered_parked"):
            if got[k] != base[k]:
                errs.append(f"recovery/{mode} {k} drifted: "
                            f"{got[k]} != pinned {base[k]}")
    warm = fresh.get("warm")
    if warm:
        if warm.get("disk_hits", 0) < 1:
            errs.append("recovery/warm disk tier served no blocks "
                        f"(disk_hits={warm.get('disk_hits')})")
        if warm.get("pool_scatter_eqns", 0) != 0:
            errs.append("recovery/warm restored round loop grew pool "
                        f"scatters ({warm['pool_scatter_eqns']})")
    # the §16 cross-mode bar, independent of the pin
    cold = fresh.get("cold")
    if warm and cold and not warm["prefill_calls"] < cold["prefill_calls"]:
        errs.append(
            f"warm restart prefill_calls not below cold: "
            f"{warm['prefill_calls']} vs {cold['prefill_calls']}")
    return errs


def main() -> int:
    rows = _load_rows(FRESH)
    fresh = _cb_rows(rows)
    fresh_rec = _recovery_rows(rows)
    if not fresh:
        print(f"perf_gate: no continuous_batching rows in {FRESH}",
              file=sys.stderr)
        return 1
    if "--update" in sys.argv:
        pinned = [dict(mode=m, batch=b, **v)
                  for (m, b), v in sorted(fresh.items())]
        pinned_rec = [dict(mode=m, **v)
                      for m, v in sorted(fresh_rec.items())]
        with open(BASELINE, "w") as f:
            json.dump({"scenario": "continuous_batching",
                       "backend": "cpu", "rows": pinned,
                       "recovery": pinned_rec}, f, indent=1)
            f.write("\n")
        print(f"perf_gate: pinned {len(pinned)} cb + "
              f"{len(pinned_rec)} recovery rows -> {BASELINE}")
        return 0
    with open(BASELINE) as f:
        pin = json.load(f)
    baseline = {(r["mode"], r["batch"]): {k: r[k] for k in KEYS}
                for r in pin["rows"]}
    baseline_rec = {r["mode"]: {k: r[k] for k in REC_KEYS}
                    for r in pin.get("recovery", [])}
    errs = check(baseline, fresh) + check_recovery(baseline_rec, fresh_rec)
    for key in sorted(fresh):
        mode, batch = key
        g = fresh[key]
        b = baseline.get(key, {})
        print(f"{mode}/B{batch}: syncs/tok {g['syncs_per_token']:.5f} "
              f"(pin {b.get('syncs_per_token', float('nan')):.5f}) "
              f"disp/tok {g['dispatches_per_token']:.5f} "
              f"occ_bk {g['occupancy_under_backlog']:.4f} "
              f"adoptions {g['in_loop_adoptions']}")
    for mode in sorted(fresh_rec):
        g = fresh_rec[mode]
        b = baseline_rec.get(mode, {})
        print(f"recovery/{mode}: prefills {g['prefill_calls']} "
              f"(pin {b.get('prefill_calls', '-')}) "
              f"recovered {g['recovered_requests']}"
              f"(parked={g['recovered_parked']}) "
              f"disk_hits {g.get('disk_hits', 0)} "
              f"scatters {g.get('pool_scatter_eqns', '-')}")
    if errs:
        print("perf_gate: FAIL", file=sys.stderr)
        for e in errs:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("perf_gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
